//! Bench-regression gating: compare a freshly emitted `BENCH_serve.json`
//! / `BENCH_train.json` against a committed baseline and report what got
//! worse (the `switchback benchdiff` subcommand, wired into CI by
//! `scripts/check_bench.sh`).
//!
//! Two comparison modes, because absolute throughput is machine-relative:
//!
//! * **portable** (default): gates only machine-independent quantities —
//!   the SwitchBack-vs-Standard throughput *ratio* and p99 *ratio* for
//!   serve, the swap-mode invariants (zero failed requests, ≥1 promotion,
//!   tail latency within [`SWAP_TAIL_FACTOR`]× of the same document's
//!   single-generation run), the scrape-under-load invariants (≥1
//!   well-formed `/metrics` scrape, zero scrape errors, scrape p99 under
//!   [`SCRAPE_P99_BUDGET_US`], and the serve tail within
//!   [`SCRAPE_TAIL_FACTOR`]× of the same document's scraper-free run),
//!   the real-TCP socket invariants (zero request errors through the
//!   front door, a clean run sheds nothing, the overload run records ≥1
//!   admission rejection, and the socket tail stays within
//!   [`SOCKET_TAIL_FACTOR`]× of the same document's in-process run),
//!   the learning invariants (loss decreased, no
//!   divergence, spike counts) for train, and — for the ckpt pipeline —
//!   the standby promote/reject/rollback/quarantine counters plus the
//!   sharded-snapshot invariants (`sharded_bit_identical`, shard count,
//!   and the shard metrics not vanishing once the baseline records them),
//!   and — for the gemm kernels — the blocked-vs-reference *speedup*
//!   curve (blocked ≥ the flat reference at the two largest shapes, and
//!   no per-shape speedup collapse vs baseline) plus the quantize-time
//!   fraction staying under [`QUANT_PCT_CEILING`].
//!   This is what CI runs against the committed baseline, which was
//!   measured on different hardware.
//! * **strict**: additionally gates absolute requests/sec, p99 and
//!   steps/sec entry-by-entry.  Use when old and new were measured on the
//!   same machine (e.g. bisecting a local regression).

use crate::util::json::Value;

/// Default allowed regression: 15% (throughput may drop, p99 may rise, by
/// at most this fraction).
pub const DEFAULT_TOLERANCE: f64 = 0.15;

/// Compare `new` against the `old` baseline; returns human-readable
/// regression descriptions (empty ⇒ gate passes).  Errors on documents
/// that are not comparable (different/unknown `bench` kinds, missing
/// `results`).
pub fn compare_bench(
    old: &Value,
    new: &Value,
    tol: f64,
    strict: bool,
) -> Result<Vec<String>, String> {
    let kind = |v: &Value| -> Result<String, String> {
        v.get("bench")
            .and_then(Value::as_str)
            .map(str::to_string)
            .ok_or_else(|| "document has no \"bench\" field".into())
    };
    // Lint ledgers identify via "schema", not "bench" — route them to the
    // suppression-monotonicity gate before the bench-kind check.
    let is_lint =
        |v: &Value| v.get("schema").and_then(Value::as_str) == Some("lint_ledger_v1");
    match (is_lint(old), is_lint(new)) {
        (true, true) => return compare_lint(old, new),
        (false, false) => {}
        _ => {
            return Err(
                "one document is a lint ledger, the other is not".to_string()
            )
        }
    }
    let (ok, nk) = (kind(old)?, kind(new)?);
    if ok != nk {
        return Err(format!("bench kinds differ: baseline {ok:?} vs new {nk:?}"));
    }
    match ok.as_str() {
        "serve_throughput" => Ok(compare_serve(old, new, tol, strict)?),
        "train_native" => Ok(compare_train(old, new, tol, strict)?),
        "ckpt_pipeline" => Ok(compare_ckpt(old, new, tol, strict)?),
        "gemm_kernels" => Ok(compare_gemm(old, new, tol, strict)?),
        other => Err(format!("unknown bench kind {other:?}")),
    }
}

// ----- lint ledger ----------------------------------------------------

/// A required lint-ledger counter; a vanished field fails closed (a
/// ledger that stops reporting a counter must not silently pass).
fn lint_num(v: &Value, ctx: &str, key: &str) -> Result<u64, String> {
    v.get(key)
        .and_then(Value::as_usize)
        .map(|n| n as u64)
        .ok_or_else(|| format!("{ctx} lint ledger: missing counter {key:?}"))
}

/// Gate a fresh `BENCH_lint.json` against the committed baseline:
///
/// * the tree must lint clean (`findings_total == 0`) with a cycle-free
///   lock graph (`lock_cycles == 0`) — absolute invariants, not ratios;
/// * `suppressed_total`, `blocking_holds` and every per-rule `sup_*`
///   counter the baseline records are monotonically non-increasing, so
///   `// lint:allow` escape hatches can be burned down but never silently
///   accumulate.
fn compare_lint(old: &Value, new: &Value) -> Result<Vec<String>, String> {
    let mut regs = Vec::new();
    let findings = lint_num(new, "new", "findings_total")?;
    if findings > 0 {
        regs.push(format!(
            "lint: {findings} unsuppressed finding(s) — the tree must lint clean"
        ));
    }
    let cycles = lint_num(new, "new", "lock_cycles")?;
    if cycles > 0 {
        regs.push(format!(
            "lint: {cycles} cycle(s) in the lock acquisition graph"
        ));
    }
    let mut monotonic: Vec<String> =
        vec!["suppressed_total".into(), "blocking_holds".into()];
    if let Value::Obj(fields) = old {
        monotonic.extend(fields.keys().filter(|k| k.starts_with("sup_")).cloned());
    }
    for key in &monotonic {
        let (o, n) = (lint_num(old, "baseline", key)?, lint_num(new, "new", key)?);
        if n > o {
            regs.push(format!(
                "lint: {key} grew {o} -> {n} — suppressions may only shrink"
            ));
        }
    }
    Ok(regs)
}

fn results(v: &Value) -> Result<&[Value], String> {
    v.get("results")
        .and_then(Value::as_arr)
        .ok_or_else(|| "document has no \"results\" array".into())
}

fn f(entry: &Value, key: &str) -> Option<f64> {
    entry.get(key).and_then(Value::as_f64)
}

/// A required numeric metric.  `null` is how the JSON writer serializes a
/// non-finite f32 (`util::json::num`), so a null metric means the
/// producing run recorded NaN/Inf — explicitly incomparable.  Fail closed
/// with a message that says so, rather than parsing it as 0 (a silent
/// pass) or panicking.
fn req_num(entry: &Value, ctx: &str, key: &str) -> Result<f64, String> {
    match entry.get(key) {
        None => Err(format!("{ctx}: missing {key:?}")),
        Some(Value::Null) => Err(format!(
            "{ctx}: {key:?} is null — the run recorded a non-finite value, \
             which is not comparable; fix the run (or the baseline) first"
        )),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| format!("{ctx}: {key:?} is not a number")),
    }
}

/// An optional numeric metric: absent is `None` (older schema), but an
/// explicit `null` still fails closed like [`req_num`].
fn opt_num(entry: &Value, ctx: &str, key: &str) -> Result<Option<f64>, String> {
    match entry.get(key) {
        None => Ok(None),
        Some(_) => req_num(entry, ctx, key).map(Some),
    }
}

fn s<'a>(entry: &'a Value, key: &str) -> &'a str {
    entry.get(key).and_then(Value::as_str).unwrap_or("?")
}

// ----- serve ----------------------------------------------------------

/// Swap-aware runs may pay tail latency for hot-swaps (the swapper
/// competes for cores while preparing a generation), but a swap-mode p99
/// beyond this multiple of the same configuration's single-generation
/// p99 means promotions are stalling the serving path — gated as an
/// invariant (machine-portable: both runs come from the same document).
pub const SWAP_TAIL_FACTOR: f64 = 10.0;

/// Absolute budget for the rider thread's p99 `/metrics` scrape latency
/// (µs).  A loopback HTTP round trip plus a registry snapshot is
/// dominated by fixed syscall/copy costs, not machine throughput, so a
/// generous absolute ceiling gates in portable mode (the same reasoning
/// as the `trace_overhead_pct` budget): 50 ms means the exposition path
/// is blocking on the serving load, not formatting text.
pub const SCRAPE_P99_BUDGET_US: f64 = 50_000.0;

/// A concurrent scraper must not move the serve tail: a scraper-present
/// run's request p99 beyond this multiple of the same configuration's
/// scraper-free run means the telemetry plane is stealing cycles from
/// the serving path — gated as a within-document invariant (both runs
/// come from the same machine, so absolute speed cancels out).
pub const SCRAPE_TAIL_FACTOR: f64 = 10.0;

/// The network front door costs a real TCP round trip per request
/// (connect once, then HTTP/1.1 framing + loopback syscalls), but a
/// socket-mode p99 beyond this multiple of the same document's
/// in-process run for the same (kind, concurrency) means the front door
/// is queueing, not serving — gated as a within-document invariant
/// (both runs come from the same machine, so absolute speed cancels
/// out).
pub const SOCKET_TAIL_FACTOR: f64 = 10.0;

/// One serve-results entry in comparable form.
struct ServeEntry {
    kind: String,
    conc: u64,
    /// swap cadence (0 = plain single-generation run)
    swap_every: u64,
    /// scrape cadence in ms (0 = no rider scraper attached)
    scrape_every: u64,
    /// clients went through a real TCP front door (`loadgen --socket`)
    socket: bool,
    /// the socket run deliberately exceeded the admission window
    overload: bool,
    rps: f64,
    p99: f64,
    errors: f64,
    /// requests shed by the admission window / a dead engine (socket
    /// entries record this from the client's ledger; 0 when absent on
    /// in-process entries)
    rejected: f64,
    /// standby promotions recorded by the run's metrics (0 when absent)
    promotions: f64,
    /// standby rejections recorded by the run's metrics (0 when absent)
    rejects: f64,
    /// well-formed scrapes completed by the rider (0 when no scraper)
    scrapes: f64,
    /// failed or malformed scrapes (0 when no scraper)
    scrape_errors: f64,
    /// p99 scrape latency in µs (0 when no scraper)
    scrape_p99_us: f64,
}

fn serve_index(v: &Value) -> Result<Vec<ServeEntry>, String> {
    results(v)?
        .iter()
        .map(|r| {
            let kind = s(r, "kind").to_string();
            let conc = f(r, "concurrency").unwrap_or(0.0) as u64;
            let swap_every = f(r, "swap_every").unwrap_or(0.0) as u64;
            let ctx = format!("serve {kind} c={conc}");
            let rps = req_num(r, &ctx, "requests_per_sec")?;
            let metrics = r
                .get("metrics")
                .ok_or_else(|| format!("{ctx}: missing \"metrics\""))?;
            let p99 = req_num(metrics, &ctx, "request_p99_ms")?;
            let errors = opt_num(r, &ctx, "errors")?.unwrap_or(0.0);
            let promotions =
                opt_num(metrics, &ctx, "standby_promotions")?.unwrap_or(0.0);
            let rejects = opt_num(metrics, &ctx, "standby_rejects")?.unwrap_or(0.0);
            // once an entry declares a scrape cadence, its scrape stats
            // are required — a scraper run missing its own measurements
            // is incomparable, not a pass
            let scrape_every =
                opt_num(r, &ctx, "scrape_every_ms")?.unwrap_or(0.0) as u64;
            let (scrapes, scrape_errors, scrape_p99_us) = if scrape_every > 0 {
                (
                    req_num(r, &ctx, "scrapes")?,
                    req_num(r, &ctx, "scrape_errors")?,
                    req_num(r, &ctx, "scrape_p99_us")?,
                )
            } else {
                (0.0, 0.0, 0.0)
            };
            // once an entry declares it went over the wire, its error and
            // shed counts are required — a socket run that lost its own
            // ledger is incomparable, not a pass
            let socket = r.get("socket").and_then(Value::as_bool).unwrap_or(false);
            let overload =
                r.get("overload").and_then(Value::as_bool).unwrap_or(false);
            let rejected = if socket {
                req_num(r, &ctx, "errors")?;
                req_num(metrics, &ctx, "rejected")?
            } else {
                opt_num(metrics, &ctx, "rejected")?.unwrap_or(0.0)
            };
            Ok(ServeEntry {
                kind,
                conc,
                swap_every,
                scrape_every,
                socket,
                overload,
                rps,
                p99,
                errors,
                rejected,
                promotions,
                rejects,
                scrapes,
                scrape_errors,
                scrape_p99_us,
            })
        })
        .collect()
}

/// A plain in-process single-generation run: no swap cadence, no rider
/// scraper, no TCP front door.  These are the entries the throughput
/// ratios and the within-document tail bounds are measured against.
fn is_plain(e: &ServeEntry) -> bool {
    e.swap_every == 0 && e.scrape_every == 0 && !e.socket
}

/// The Standard-vs-SwitchBack ratios per concurrency (machine-portable),
/// over the plain single-generation, scraper-free, in-process runs only.
fn serve_ratios(idx: &[ServeEntry]) -> Vec<(u64, f64, f64)> {
    let mut out = vec![];
    for e in idx {
        if e.kind != "switchback" || !is_plain(e) {
            continue;
        }
        if let Some(std_e) = idx
            .iter()
            .find(|o| o.kind == "standard" && o.conc == e.conc && is_plain(o))
        {
            if std_e.rps > 0.0 && e.p99 > 0.0 {
                out.push((e.conc, e.rps / std_e.rps, std_e.p99 / e.p99));
            }
        }
    }
    out
}

fn compare_serve(
    old: &Value,
    new: &Value,
    tol: f64,
    strict: bool,
) -> Result<Vec<String>, String> {
    let oi = serve_index(old)?;
    let ni = serve_index(new)?;
    // fail closed if the swap-aware run disappeared: the baseline gates
    // its invariants, and "no entry" must not read as "no regression"
    if oi.iter().any(|e| e.swap_every > 0) && !ni.iter().any(|e| e.swap_every > 0) {
        return Err(
            "baseline has a --swap-every entry but the new document has \
             none — the swap-aware run disappeared; restore it (or refresh \
             the baseline) before comparing"
                .into(),
        );
    }
    // same rule for the scraper-present run: once the baseline gates the
    // scrape-under-load invariants, the entry vanishing must not read as
    // "no regression"
    if oi.iter().any(|e| e.scrape_every > 0)
        && !ni.iter().any(|e| e.scrape_every > 0)
    {
        return Err(
            "baseline has a --scrape-every entry but the new document has \
             none — the scrape-under-load run disappeared; restore it (or \
             refresh the baseline) before comparing"
                .into(),
        );
    }
    // …and for the real-TCP runs: both the clean socket entry and the
    // overload entry carry gated invariants, so either vanishing fails
    // closed on its own
    for (overload, what) in [(false, "clean"), (true, "overload")] {
        if oi.iter().any(|e| e.socket && e.overload == overload)
            && !ni.iter().any(|e| e.socket && e.overload == overload)
        {
            return Err(format!(
                "baseline has a --socket {what} entry but the new document \
                 has none — the real-TCP run disappeared; restore it (or \
                 refresh the baseline) before comparing"
            ));
        }
    }
    let mut regs = vec![];
    let mut compared = 0usize;
    // portable: the int8-vs-f32 ratios must not regress
    let old_ratios = serve_ratios(&oi);
    for (conc, new_tput_ratio, new_p99_ratio) in serve_ratios(&ni) {
        let Some(&(_, old_tput_ratio, old_p99_ratio)) =
            old_ratios.iter().find(|(c, _, _)| *c == conc)
        else {
            continue;
        };
        compared += 1;
        if new_tput_ratio < old_tput_ratio * (1.0 - tol) {
            regs.push(format!(
                "serve c={conc}: switchback/standard throughput ratio fell \
                 {old_tput_ratio:.2}× → {new_tput_ratio:.2}× (> {:.0}% drop)",
                tol * 100.0
            ));
        }
        if new_p99_ratio < old_p99_ratio * (1.0 - tol) {
            regs.push(format!(
                "serve c={conc}: standard/switchback p99 ratio fell \
                 {old_p99_ratio:.2} → {new_p99_ratio:.2} (switchback p99 regressed)"
            ));
        }
    }
    // portable swap invariants: a --swap-every run must drop nothing,
    // actually promote generations, and keep its tail latency within
    // SWAP_TAIL_FACTOR of the same configuration's single-generation run
    // (a within-document bound, so machine speed cancels out)
    for e in ni.iter().filter(|e| e.swap_every > 0) {
        compared += 1;
        let tag = format!("serve {} c={} swap-every={}", e.kind, e.conc, e.swap_every);
        if e.errors > 0.0 {
            regs.push(format!(
                "{tag}: {:.0} requests failed across generations",
                e.errors
            ));
        }
        if e.promotions < 1.0 {
            regs.push(format!("{tag}: no generation was promoted"));
        }
        if e.rejects > 0.0 {
            regs.push(format!(
                "{tag}: {:.0} promotion(s) failed validation \
                 (fresh-seeded generations must always install)",
                e.rejects
            ));
        }
        if let Some(plain) = ni
            .iter()
            .find(|o| o.kind == e.kind && o.conc == e.conc && is_plain(o))
        {
            if plain.p99 > 0.0 && e.p99 > plain.p99 * SWAP_TAIL_FACTOR {
                regs.push(format!(
                    "{tag}: swap-tail-latency invariant broken — p99 \
                     {:.2} ms vs {:.2} ms single-generation (> {SWAP_TAIL_FACTOR}×)",
                    e.p99, plain.p99
                ));
            }
        }
    }
    // portable scrape-under-load invariants: a --scrape-every run must
    // actually scrape, every scrape must come back well-formed, the
    // scrape tail stays under the absolute budget, and the serving path's
    // own tail stays within SCRAPE_TAIL_FACTOR of the same
    // configuration's scraper-free run (a within-document bound)
    for e in ni.iter().filter(|e| e.scrape_every > 0) {
        compared += 1;
        let tag = format!(
            "serve {} c={} scrape-every={}ms",
            e.kind, e.conc, e.scrape_every
        );
        if e.errors > 0.0 {
            regs.push(format!(
                "{tag}: {:.0} requests failed under a concurrent scraper",
                e.errors
            ));
        }
        if e.scrapes < 1.0 {
            regs.push(format!(
                "{tag}: the rider thread completed no scrapes — the \
                 telemetry plane was never exercised under load"
            ));
        }
        if e.scrape_errors > 0.0 {
            regs.push(format!(
                "{tag}: {:.0} scrape(s) failed or returned a malformed \
                 exposition",
                e.scrape_errors
            ));
        }
        if e.scrapes >= 1.0 && e.scrape_p99_us > SCRAPE_P99_BUDGET_US {
            regs.push(format!(
                "{tag}: scrape p99 {:.0} µs exceeds the \
                 {SCRAPE_P99_BUDGET_US:.0} µs budget — the exposition \
                 path is blocking on the serving load",
                e.scrape_p99_us
            ));
        }
        if let Some(plain) = ni
            .iter()
            .find(|o| o.kind == e.kind && o.conc == e.conc && is_plain(o))
        {
            if plain.p99 > 0.0 && e.p99 > plain.p99 * SCRAPE_TAIL_FACTOR {
                regs.push(format!(
                    "{tag}: scrape-tail-latency invariant broken — serve \
                     p99 {:.2} ms vs {:.2} ms scraper-free \
                     (> {SCRAPE_TAIL_FACTOR}×): the scraper moved the \
                     serve tail",
                    e.p99, plain.p99
                ));
            }
        }
    }
    // portable socket invariants: every real-TCP run must lose nothing
    // (failed requests mean the door broke mid-conversation), the clean
    // run must stay inside the admission window (a shed there means the
    // window is mis-sized), the overload run must actually overload (≥1
    // rejection, or the bound was never exercised), and the clean run's
    // tail must stay within SOCKET_TAIL_FACTOR of the same
    // configuration's in-process run (the front door may tax, not queue)
    for e in ni.iter().filter(|e| e.socket) {
        compared += 1;
        let tag = format!(
            "serve {} c={} socket{}",
            e.kind,
            e.conc,
            if e.overload { " overload" } else { "" }
        );
        if e.errors > 0.0 {
            regs.push(format!(
                "{tag}: {:.0} requests failed through the front door",
                e.errors
            ));
        }
        if e.overload && e.rejected < 1.0 {
            regs.push(format!(
                "{tag}: no admission rejections — the overload run never \
                 filled the window, the 429 path went unexercised"
            ));
        }
        if !e.overload {
            if e.rejected > 0.0 {
                regs.push(format!(
                    "{tag}: {:.0} request(s) shed under the admission window \
                     (the clean run must not overload)",
                    e.rejected
                ));
            }
            match ni
                .iter()
                .find(|o| o.kind == e.kind && o.conc == e.conc && is_plain(o))
            {
                Some(plain) => {
                    if plain.p99 > 0.0 && e.p99 > plain.p99 * SOCKET_TAIL_FACTOR {
                        regs.push(format!(
                            "{tag}: socket-tail-latency invariant broken — p99 \
                             {:.2} ms vs {:.2} ms in-process \
                             (> {SOCKET_TAIL_FACTOR}×): the front door is \
                             queueing, not serving",
                            e.p99, plain.p99
                        ));
                    }
                }
                // the bound needs its in-process anchor: absence must not
                // read as a pass
                None => regs.push(format!(
                    "{tag}: no in-process entry for the same (kind, \
                     concurrency) to bound the socket tail against"
                )),
            }
        }
    }
    if strict {
        for e in &ni {
            let Some(o) = oi.iter().find(|o| {
                o.kind == e.kind
                    && o.conc == e.conc
                    && o.swap_every == e.swap_every
                    && o.scrape_every == e.scrape_every
                    && o.socket == e.socket
                    && o.overload == e.overload
            }) else {
                continue;
            };
            compared += 1;
            if e.rps < o.rps * (1.0 - tol) {
                regs.push(format!(
                    "serve {} c={}: throughput {:.0} → {:.0} req/s \
                     (> {:.0}% drop)",
                    e.kind,
                    e.conc,
                    o.rps,
                    e.rps,
                    tol * 100.0
                ));
            }
            if e.p99 > o.p99 * (1.0 + tol) {
                regs.push(format!(
                    "serve {} c={}: p99 {:.2} → {:.2} ms (> {:.0}% rise)",
                    e.kind,
                    e.conc,
                    o.p99,
                    e.p99,
                    tol * 100.0
                ));
            }
        }
    }
    // the gate must never pass vacuously: if nothing lined up between the
    // two documents, that is itself a failure of the comparison
    if compared == 0 {
        return Err(
            "nothing comparable between baseline and new serve results \
             (no standard/switchback pair, no swap-every entry, and no \
             matching (kind, concurrency) entries)"
                .into(),
        );
    }
    Ok(regs)
}

// ----- train ----------------------------------------------------------

/// Absolute ceiling for the span tracer's estimated share of step wall
/// time (`trace_overhead_pct` in BENCH_train.json).  It is a ratio of two
/// same-machine clocks, so it gates in portable mode, not just strict.
const TRACE_OVERHEAD_BUDGET_PCT: f64 = 3.0;

fn compare_train(
    old: &Value,
    new: &Value,
    tol: f64,
    strict: bool,
) -> Result<Vec<String>, String> {
    let on = results(old)?;
    let nn = results(new)?;
    if nn.is_empty() {
        return Err("new train document has no results".into());
    }
    let mut regs = vec![];
    let mut matched = 0usize;
    for r in nn {
        let key = (s(r, "kind").to_string(), s(r, "optimizer").to_string());
        let tag = format!("train {}/{}", key.0, key.1);
        let first = req_num(r, &tag, "first_loss")?;
        let fin = req_num(r, &tag, "final_loss")?;
        // portable learning invariants: the run must still learn
        if r.get("diverged").and_then(Value::as_bool).unwrap_or(false) {
            regs.push(format!("{tag}: run diverged"));
        }
        if fin.is_nan() || first.is_nan() || fin >= first {
            regs.push(format!(
                "{tag}: loss no longer decreases ({first:.4} → {fin:.4})"
            ));
        }
        // tracing must stay effectively free: the tracer's share of step
        // time is budgeted absolutely, independent of any baseline
        if let Some(ov) = opt_num(r, &tag, "trace_overhead_pct")? {
            if !ov.is_finite() || ov > TRACE_OVERHEAD_BUDGET_PCT {
                regs.push(format!(
                    "{tag}: trace_overhead_pct {ov:.2} exceeds the \
                     {TRACE_OVERHEAD_BUDGET_PCT}% budget"
                ));
            }
        }
        let Some(o) = on
            .iter()
            .find(|o| s(o, "kind") == key.0 && s(o, "optimizer") == key.1)
        else {
            continue;
        };
        matched += 1;
        // once the baseline records the overhead metric it must not vanish
        // from a fresh run — absence never reads as a pass
        if o.get("trace_overhead_pct").is_some()
            && r.get("trace_overhead_pct").is_none()
        {
            regs.push(format!(
                "{tag}: baseline records trace_overhead_pct but the new run \
                 omits it"
            ));
        }
        let (ospikes, nspikes) = (
            opt_num(o, &tag, "loss_spikes")?.unwrap_or(0.0),
            opt_num(r, &tag, "loss_spikes")?.unwrap_or(0.0),
        );
        if nspikes > ospikes + 1.0 {
            regs.push(format!(
                "{tag}: loss spikes {ospikes:.0} → {nspikes:.0} (stability regressed)"
            ));
        }
        if strict {
            let (osps, nsps) = (
                opt_num(o, &tag, "steps_per_sec")?.unwrap_or(0.0),
                opt_num(r, &tag, "steps_per_sec")?.unwrap_or(0.0),
            );
            if osps > 0.0 && nsps < osps * (1.0 - tol) {
                regs.push(format!(
                    "{tag}: throughput {osps:.2} → {nsps:.2} steps/s (> {:.0}% drop)",
                    tol * 100.0
                ));
            }
            let ofin = opt_num(o, &tag, "final_loss")?.unwrap_or(f64::NAN);
            if ofin.is_finite() && fin > ofin * (1.0 + tol) {
                regs.push(format!(
                    "{tag}: final loss {ofin:.4} → {fin:.4} (> {:.0}% rise)",
                    tol * 100.0
                ));
            }
        }
    }
    if matched == 0 {
        return Err(
            "no (kind, optimizer) pairs matched between baseline and new \
             train results"
                .into(),
        );
    }
    Ok(regs)
}

// ----- ckpt pipeline --------------------------------------------------

/// BENCH_ckpt.json gate.  Portable invariants (machine-independent, and
/// deterministic by construction on this substrate): zero dropped requests
/// across the hot-swap, bit-identical checkpoint round trip, serve/train
/// encode parity, cache invalidation, and the zero-shot accuracy of the
/// served weights.  Strict additionally gates save/load MB/s and the
/// hot-swap pause (same-machine absolutes).
fn compare_ckpt(
    old: &Value,
    new: &Value,
    tol: f64,
    strict: bool,
) -> Result<Vec<String>, String> {
    let on = results(old)?;
    let nn = results(new)?;
    if nn.is_empty() {
        return Err("new ckpt document has no results".into());
    }
    let mut regs = vec![];
    let mut matched = 0usize;
    for r in nn {
        let kind = s(r, "kind").to_string();
        let tag = format!("ckpt {kind}");
        let dropped = req_num(r, &tag, "dropped_requests")?;
        if dropped > 0.0 {
            regs.push(format!(
                "{tag}: {dropped:.0} requests dropped across the hot-swap"
            ));
        }
        for (key, what) in [
            ("round_trip_ok", "checkpoint round trip is no longer bit-identical"),
            ("eval_matches_model", "serve/train encode parity broke"),
            ("cache_invalidated", "hot-swap no longer invalidates the cache"),
            ("weights_changed", "hot-swap did not actually change the weights"),
        ] {
            if !r.get(key).and_then(Value::as_bool).unwrap_or(false) {
                regs.push(format!("{tag}: {what} ({key} != true)"));
            }
        }
        // standby invariants (present since the watcher-driven pipeline):
        // rollbacks mean a promoted generation failed its live canary
        // probe — never expected from a clean pipeline run
        if let Some(rb) = opt_num(r, &tag, "standby_rollbacks")? {
            if rb > 0.0 {
                regs.push(format!(
                    "{tag}: {rb:.0} unexpected post-promotion rollback(s)"
                ));
            }
        }
        // a quarantine means the watcher gave up on a staged snapshot —
        // the pipeline's atomic staging must never produce one
        if let Some(q) = opt_num(r, &tag, "standby_quarantines")? {
            if q > 0.0 {
                regs.push(format!(
                    "{tag}: {q:.0} snapshot(s) quarantined by the standby watcher"
                ));
            }
        }
        // sharded-snapshot invariant (present since the v2 pipeline): the
        // async sharded save must stay bit-identical to the sync v1 save
        if let Some(v) = r.get("sharded_bit_identical") {
            if v.as_bool() != Some(true) {
                regs.push(format!(
                    "{tag}: sharded async snapshot no longer bit-identical \
                     to the synchronous save (sharded_bit_identical != true)"
                ));
            }
        }
        let acc = req_num(r, &tag, "eval_acc")?;
        let Some(o) = on.iter().find(|o| s(o, "kind") == kind) else {
            continue;
        };
        matched += 1;
        // watcher throughput of the promote/reject state machine must not
        // shrink vs the baseline scenario (same pipeline shape on both
        // sides, so the counts are deterministic)
        for (key, what) in [
            ("standby_promotions", "watcher-driven promotions"),
            ("standby_rejects", "canary rejections of injected drift"),
        ] {
            match (opt_num(o, &tag, key)?, opt_num(r, &tag, key)?) {
                (Some(ov), Some(nv)) => {
                    if nv < ov {
                        regs.push(format!("{tag}: {what} fell {ov:.0} → {nv:.0}"));
                    }
                }
                // gated data vanished from the fresh run: fail closed,
                // absence must not read as a pass
                (Some(ov), None) => regs.push(format!(
                    "{tag}: baseline records {key} ({ov:.0}) but the new \
                     run omits it"
                )),
                _ => {}
            }
        }
        // shard metrics must not vanish once the baseline records them —
        // absence of gated data never reads as a pass (the same rule the
        // standby counters follow)
        for key in [
            "ckpt_shards",
            "shard_save_mb_s",
            "shard_load_mb_s",
            "sharded_bit_identical",
        ] {
            if o.get(key).is_some() && r.get(key).is_none() {
                regs.push(format!(
                    "{tag}: baseline records {key} but the new run omits it"
                ));
            }
        }
        // the scenario's shard count is deterministic: falling below the
        // baseline means the sharded path silently stopped being exercised
        if let (Some(ov), Some(nv)) = (
            opt_num(o, &tag, "ckpt_shards")?,
            opt_num(r, &tag, "ckpt_shards")?,
        ) {
            if nv < ov {
                regs.push(format!(
                    "{tag}: pipeline shard count fell {ov:.0} → {nv:.0}"
                ));
            }
        }
        let oacc = req_num(o, &tag, "eval_acc")?;
        if oacc > 0.0 && acc < oacc * (1.0 - tol) {
            regs.push(format!(
                "{tag}: served zero-shot acc {oacc:.3} → {acc:.3} (> {:.0}% drop)",
                tol * 100.0
            ));
        }
        if strict {
            for key in ["save_mb_s", "load_mb_s"] {
                let (ov, nv) = (req_num(o, &tag, key)?, req_num(r, &tag, key)?);
                if ov > 0.0 && nv < ov * (1.0 - tol) {
                    regs.push(format!(
                        "{tag}: {key} {ov:.1} → {nv:.1} MB/s (> {:.0}% drop)",
                        tol * 100.0
                    ));
                }
            }
            // shard throughput: machine absolutes, gated only when both
            // documents carry them (older baselines predate the fields)
            for key in ["shard_save_mb_s", "shard_load_mb_s"] {
                if let (Some(ov), Some(nv)) =
                    (opt_num(o, &tag, key)?, opt_num(r, &tag, key)?)
                {
                    if ov > 0.0 && nv < ov * (1.0 - tol) {
                        regs.push(format!(
                            "{tag}: {key} {ov:.1} → {nv:.1} MB/s (> {:.0}% drop)",
                            tol * 100.0
                        ));
                    }
                }
            }
            let (op, np) = (
                req_num(o, &tag, "hot_swap_pause_us")?,
                req_num(r, &tag, "hot_swap_pause_us")?,
            );
            if op > 0.0 && np > op * (1.0 + tol) {
                regs.push(format!(
                    "{tag}: hot-swap pause {op:.1} → {np:.1} µs (> {:.0}% rise)",
                    tol * 100.0
                ));
            }
        }
    }
    if matched == 0 {
        return Err("no kinds matched between baseline and new ckpt results".into());
    }
    Ok(regs)
}

// ----- gemm kernels ---------------------------------------------------

/// Portable ceiling on the quantize fraction at the largest benched dim
/// (paper Fig 4: ≤25% and falling with dim — 50% means the quantize ops
/// around the GEMM have eaten the int8 win).
pub const QUANT_PCT_CEILING: f64 = 50.0;

/// One BENCH_gemm.json kernel entry in comparable form.
struct GemmEntry {
    name: String,
    /// b·k·m — the ordering key for "largest shapes"
    work: f64,
    f32_ms: f64,
    reference_ms: f64,
    blocked_ms: f64,
    blocked_speedup: f64,
}

fn gemm_index(v: &Value) -> Result<Vec<GemmEntry>, String> {
    results(v)?
        .iter()
        .map(|r| {
            let name = s(r, "name").to_string();
            let ctx = format!("gemm {name}");
            let work = req_num(r, &ctx, "b")?
                * req_num(r, &ctx, "k")?
                * req_num(r, &ctx, "m")?;
            Ok(GemmEntry {
                work,
                f32_ms: req_num(r, &ctx, "f32_ms")?,
                reference_ms: req_num(r, &ctx, "reference_ms")?,
                blocked_ms: req_num(r, &ctx, "blocked_ms")?,
                blocked_speedup: req_num(r, &ctx, "blocked_speedup")?,
                name,
            })
        })
        .collect()
}

fn compare_gemm(
    old: &Value,
    new: &Value,
    tol: f64,
    strict: bool,
) -> Result<Vec<String>, String> {
    let oi = gemm_index(old)?;
    let ni = gemm_index(new)?;
    // fail closed on vanishing coverage: every baseline shape must still
    // be measured — "no entry" must not read as "no regression"
    for o in &oi {
        if !ni.iter().any(|n| n.name == o.name) {
            return Err(format!(
                "gemm: baseline shape {:?} is missing from the new document \
                 — the bench lost coverage; restore the shape (or refresh \
                 the baseline) before comparing",
                o.name
            ));
        }
    }
    let mut regs = vec![];
    let mut compared = 0usize;
    // portable invariant: at the two largest shapes the blocked kernel
    // must be at least as fast as the flat reference kernel (a ratio of
    // two same-machine kernels, so machine speed cancels out)
    let mut by_work: Vec<&GemmEntry> = ni.iter().collect();
    by_work.sort_by(|a, b| b.work.partial_cmp(&a.work).unwrap());
    for e in by_work.iter().take(2) {
        compared += 1;
        if e.blocked_speedup < 1.0 - tol {
            regs.push(format!(
                "gemm {}: blocked kernel slower than the flat reference \
                 ({:.2}x, want ≥ 1.0x within {:.0}% tol)",
                e.name,
                e.blocked_speedup,
                tol * 100.0
            ));
        }
    }
    // portable: the speedup-vs-size curve must not regress vs baseline
    for e in &ni {
        let Some(o) = oi.iter().find(|o| o.name == e.name) else {
            continue; // new shape with no baseline: nothing to gate yet
        };
        compared += 1;
        if e.blocked_speedup < o.blocked_speedup * (1.0 - tol) {
            regs.push(format!(
                "gemm {}: blocked-vs-reference speedup fell {:.2}x → {:.2}x \
                 (> {:.0}% drop)",
                e.name,
                o.blocked_speedup,
                e.blocked_speedup,
                tol * 100.0
            ));
        }
        if strict {
            for (key, ov, nv) in [
                ("f32_ms", o.f32_ms, e.f32_ms),
                ("reference_ms", o.reference_ms, e.reference_ms),
                ("blocked_ms", o.blocked_ms, e.blocked_ms),
            ] {
                if ov > 0.0 && nv > ov * (1.0 + tol) {
                    regs.push(format!(
                        "gemm {}: {key} {ov:.3} → {nv:.3} ms (> {:.0}% rise)",
                        e.name,
                        tol * 100.0
                    ));
                }
            }
        }
    }
    // quant-fraction block (embedded from the fig4 bench): once the
    // baseline records it, it vanishing from the new document fails closed
    let oq = old.get("quant_fraction").and_then(Value::as_arr);
    let nq = new.get("quant_fraction").and_then(Value::as_arr);
    match (oq, nq) {
        (Some(_), None) => {
            return Err(
                "gemm: baseline has a \"quant_fraction\" block but the new \
                 document has none — the quant-fraction bench disappeared; \
                 restore it (or refresh the baseline) before comparing"
                    .into(),
            );
        }
        (_, Some(nq)) => {
            // portable: the quantize share at the largest dim stays sane
            let mut largest: Option<(f64, f64)> = None;
            for e in nq {
                let dim = req_num(e, "gemm quant_fraction", "dim")?;
                let pct = req_num(e, "gemm quant_fraction", "quant_pct")?;
                if largest.map(|(d, _)| dim > d).unwrap_or(true) {
                    largest = Some((dim, pct));
                }
            }
            if let Some((dim, pct)) = largest {
                compared += 1;
                if pct > QUANT_PCT_CEILING {
                    regs.push(format!(
                        "gemm: quantize fraction at dim {dim:.0} is \
                         {pct:.1}% (> {QUANT_PCT_CEILING:.0}% ceiling — \
                         quantize overhead is eating the int8 win)"
                    ));
                }
            }
            if strict {
                if let Some(oq) = oq {
                    for e in nq {
                        let dim = req_num(e, "gemm quant_fraction", "dim")?;
                        let Some(o) = oq.iter().find(|o| {
                            f(o, "dim").map(|d| d == dim).unwrap_or(false)
                        }) else {
                            continue;
                        };
                        compared += 1;
                        for key in ["quant_ms", "matmul_ms"] {
                            let ctx = format!("gemm quant_fraction dim {dim:.0}");
                            let (ov, nv) =
                                (req_num(o, &ctx, key)?, req_num(e, &ctx, key)?);
                            if ov > 0.0 && nv > ov * (1.0 + tol) {
                                regs.push(format!(
                                    "{ctx}: {key} {ov:.3} → {nv:.3} ms \
                                     (> {:.0}% rise)",
                                    tol * 100.0
                                ));
                            }
                        }
                    }
                }
            }
        }
        (None, None) => {}
    }
    if compared == 0 {
        return Err(
            "nothing comparable between baseline and new gemm results \
             (no matching shape names and no quant_fraction block)"
                .into(),
        );
    }
    Ok(regs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn lint_doc(findings: u64, sup: u64, cycles: u64, holds: u64, sup_npp: u64) -> Value {
        parse(&format!(
            r#"{{"schema":"lint_ledger_v1","files":70,
                "findings_total":{findings},"suppressed_total":{sup},
                "rule_no_panic_path":0,"sup_no_panic_path":{sup_npp},
                "lock_nodes":9,"lock_edges":1,
                "lock_cycles":{cycles},"blocking_holds":{holds},
                "lock_functions":400}}"#
        ))
        .unwrap()
    }

    #[test]
    fn lint_clean_tree_passes() {
        let base = lint_doc(0, 1, 0, 0, 1);
        let new = lint_doc(0, 1, 0, 0, 1);
        assert!(compare_bench(&base, &new, 0.15, false).unwrap().is_empty());
    }

    #[test]
    fn lint_any_active_finding_fails() {
        let base = lint_doc(0, 1, 0, 0, 1);
        let new = lint_doc(3, 1, 0, 0, 1);
        let regs = compare_bench(&base, &new, 0.15, false).unwrap();
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("3 unsuppressed"), "{regs:?}");
    }

    #[test]
    fn lint_cycle_fails() {
        let base = lint_doc(0, 1, 0, 0, 1);
        let new = lint_doc(0, 1, 2, 0, 1);
        let regs = compare_bench(&base, &new, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("cycle")), "{regs:?}");
    }

    #[test]
    fn lint_suppressions_may_shrink_but_not_grow() {
        let base = lint_doc(0, 1, 0, 0, 1);
        let fewer = lint_doc(0, 0, 0, 0, 0);
        assert!(compare_bench(&base, &fewer, 0.15, false).unwrap().is_empty());
        let more = lint_doc(0, 2, 0, 0, 2);
        let regs = compare_bench(&base, &more, 0.15, false).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("suppressed_total grew 1 -> 2")),
            "{regs:?}"
        );
        assert!(
            regs.iter().any(|r| r.contains("sup_no_panic_path grew 1 -> 2")),
            "{regs:?}"
        );
    }

    #[test]
    fn lint_blocking_holds_may_not_grow() {
        let base = lint_doc(0, 1, 0, 0, 1);
        let new = lint_doc(0, 1, 0, 1, 1);
        let regs = compare_bench(&base, &new, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("blocking_holds")), "{regs:?}");
    }

    #[test]
    fn lint_vanished_counter_fails_closed() {
        let base = lint_doc(0, 1, 0, 0, 1);
        let mut gutted = String::from(
            r#"{"schema":"lint_ledger_v1","files":70,"findings_total":0,
                "lock_cycles":0,"blocking_holds":0}"#,
        );
        gutted.retain(|c| c != '\n');
        let new = parse(&gutted).unwrap();
        let err = compare_bench(&base, &new, 0.15, false).unwrap_err();
        assert!(err.contains("suppressed_total"), "{err}");
    }

    #[test]
    fn lint_vs_bench_document_is_an_error() {
        let lint = lint_doc(0, 1, 0, 0, 1);
        let serve = serve_doc(100.0, 120.0, 10.0, 9.0);
        assert!(compare_bench(&lint, &serve, 0.15, false).is_err());
        assert!(compare_bench(&serve, &lint, 0.15, false).is_err());
    }

    fn serve_doc(std_rps: f64, sb_rps: f64, std_p99: f64, sb_p99: f64) -> Value {
        parse(&format!(
            r#"{{"bench":"serve_throughput","policy":{{}},"results":[
                {{"kind":"standard","concurrency":16,"requests_per_sec":{std_rps},
                  "metrics":{{"request_p99_ms":{std_p99}}}}},
                {{"kind":"switchback","concurrency":16,"requests_per_sec":{sb_rps},
                  "metrics":{{"request_p99_ms":{sb_p99}}}}}
            ]}}"#
        ))
        .unwrap()
    }

    fn train_doc(first: f64, fin: f64, sps: f64, spikes: u64, diverged: bool) -> Value {
        parse(&format!(
            r#"{{"bench":"train_native","config":{{}},"results":[
                {{"kind":"switchback","optimizer":"stable_adamw",
                  "first_loss":{first},"final_loss":{fin},
                  "steps_per_sec":{sps},"loss_spikes":{spikes},
                  "diverged":{diverged}}}
            ]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn portable_serve_passes_across_machines() {
        // same 1.5× ratio at wildly different absolute speeds: no regression
        let old = serve_doc(1000.0, 1500.0, 10.0, 8.0);
        let new = serve_doc(200.0, 300.0, 50.0, 40.0);
        let regs = compare_bench(&old, &new, 0.15, false).unwrap();
        assert!(regs.is_empty(), "{regs:?}");
        // strict mode *does* flag the absolute collapse
        let regs = compare_bench(&old, &new, 0.15, true).unwrap();
        assert!(!regs.is_empty());
    }

    #[test]
    fn serve_ratio_regression_is_caught() {
        let old = serve_doc(1000.0, 1500.0, 10.0, 8.0); // 1.5×
        let new = serve_doc(1000.0, 1100.0, 10.0, 8.0); // 1.1× < 1.5·0.85
        let regs = compare_bench(&old, &new, 0.15, false).unwrap();
        assert_eq!(regs.len(), 1);
        assert!(regs[0].contains("throughput ratio"), "{}", regs[0]);
    }

    #[test]
    fn serve_p99_ratio_regression_is_caught() {
        let old = serve_doc(1000.0, 1500.0, 10.0, 8.0);
        let new = serve_doc(1000.0, 1500.0, 10.0, 20.0); // sb p99 doubled
        let regs = compare_bench(&old, &new, 0.15, false).unwrap();
        assert_eq!(regs.len(), 1);
        assert!(regs[0].contains("p99"), "{}", regs[0]);
    }

    #[test]
    fn train_learning_invariants() {
        let old = train_doc(3.4, 2.1, 12.0, 0, false);
        // still learns, slightly different loss: fine
        let new = train_doc(3.4, 2.3, 6.0, 0, false);
        assert!(compare_bench(&old, &new, 0.15, false).unwrap().is_empty());
        // loss stopped decreasing: caught
        let bad = train_doc(3.4, 3.6, 12.0, 0, false);
        let regs = compare_bench(&old, &bad, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("no longer decreases")), "{regs:?}");
        // divergence: caught
        let div = train_doc(3.4, 2.0, 12.0, 0, true);
        let regs = compare_bench(&old, &div, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("diverged")), "{regs:?}");
        // new spikes: caught
        let spiky = train_doc(3.4, 2.1, 12.0, 3, false);
        let regs = compare_bench(&old, &spiky, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("spikes")), "{regs:?}");
        // strict flags the 2× slowdown
        let regs = compare_bench(&old, &new, 0.15, true).unwrap();
        assert!(regs.iter().any(|r| r.contains("steps/s")), "{regs:?}");
    }

    fn train_doc_with_overhead(overhead: Option<f64>) -> Value {
        let field = match overhead {
            Some(v) => format!(r#""trace_overhead_pct":{v},"#),
            None => String::new(),
        };
        parse(&format!(
            r#"{{"bench":"train_native","config":{{}},"results":[
                {{"kind":"switchback","optimizer":"stable_adamw",
                  "first_loss":3.4,"final_loss":2.1,
                  "steps_per_sec":12.0,"loss_spikes":0,{field}
                  "diverged":false}}
            ]}}"#
        ))
        .unwrap()
    }

    /// The tracer-overhead gate: within budget passes, over budget fails
    /// in portable mode, and the field vanishing from a fresh run while
    /// the baseline records it fails closed.
    #[test]
    fn trace_overhead_is_gated_and_fails_closed() {
        let old = train_doc_with_overhead(Some(0.5));
        let ok = train_doc_with_overhead(Some(1.2));
        assert!(compare_bench(&old, &ok, 0.15, false).unwrap().is_empty());
        // blown budget: caught without strict mode
        let hot = train_doc_with_overhead(Some(7.5));
        let regs = compare_bench(&old, &hot, 0.15, false).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("trace_overhead_pct")),
            "{regs:?}"
        );
        // field dropped while the baseline records it: caught
        let gone = train_doc_with_overhead(None);
        let regs = compare_bench(&old, &gone, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("omits it")), "{regs:?}");
        // pre-tracing baseline against an instrumented run: no complaint
        let regs = compare_bench(&gone, &ok, 0.15, false).unwrap();
        assert!(regs.is_empty(), "{regs:?}");
    }

    #[test]
    fn vacuous_comparisons_fail_closed() {
        // same bench kind but nothing lines up (different concurrency):
        // must error, not silently pass
        let old = serve_doc(1000.0, 1500.0, 10.0, 8.0);
        let mut other = serve_doc(1000.0, 1500.0, 10.0, 8.0);
        if let Value::Obj(m) = &mut other {
            if let Some(Value::Arr(rs)) = m.get_mut("results") {
                for r in rs {
                    if let Value::Obj(e) = r {
                        e.insert("concurrency".into(), Value::Num(32.0));
                    }
                }
            }
        }
        assert!(compare_bench(&old, &other, 0.15, false).is_err());
        // train: empty new results must error
        let tr = train_doc(3.4, 2.1, 12.0, 0, false);
        let empty = parse(r#"{"bench":"train_native","results":[]}"#).unwrap();
        assert!(compare_bench(&tr, &empty, 0.15, false).is_err());
        // train: no matching (kind, optimizer) must error
        let mut lion = train_doc(3.4, 2.1, 12.0, 0, false);
        if let Value::Obj(m) = &mut lion {
            if let Some(Value::Arr(rs)) = m.get_mut("results") {
                for r in rs {
                    if let Value::Obj(e) = r {
                        e.insert("optimizer".into(), Value::Str("lion".into()));
                    }
                }
            }
        }
        assert!(compare_bench(&tr, &lion, 0.15, false).is_err());
    }

    fn ckpt_doc(
        dropped: u64,
        round_trip: bool,
        acc: f64,
        save: f64,
        pause: f64,
    ) -> Value {
        parse(&format!(
            r#"{{"bench":"ckpt_pipeline","config":{{}},"results":[
                {{"kind":"switchback","dropped_requests":{dropped},
                  "round_trip_ok":{round_trip},"eval_matches_model":true,
                  "cache_invalidated":true,"weights_changed":true,
                  "eval_acc":{acc},"save_mb_s":{save},"load_mb_s":{save},
                  "hot_swap_pause_us":{pause}}}
            ]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn ckpt_invariants_are_gated() {
        let good = ckpt_doc(0, true, 0.8, 100.0, 50.0);
        assert!(compare_bench(&good, &good, 0.15, false).unwrap().is_empty());
        // dropped requests across the swap: caught
        let drops = ckpt_doc(3, true, 0.8, 100.0, 50.0);
        let regs = compare_bench(&good, &drops, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("dropped")), "{regs:?}");
        // broken round trip: caught
        let broken = ckpt_doc(0, false, 0.8, 100.0, 50.0);
        let regs = compare_bench(&good, &broken, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("round trip")), "{regs:?}");
        // served accuracy collapse: caught
        let dumb = ckpt_doc(0, true, 0.3, 100.0, 50.0);
        let regs = compare_bench(&good, &dumb, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("zero-shot")), "{regs:?}");
        // portable mode ignores machine absolutes; strict gates them
        let slow = ckpt_doc(0, true, 0.8, 10.0, 500.0);
        assert!(compare_bench(&good, &slow, 0.15, false).unwrap().is_empty());
        let regs = compare_bench(&good, &slow, 0.15, true).unwrap();
        assert!(regs.iter().any(|r| r.contains("save_mb_s")), "{regs:?}");
        assert!(regs.iter().any(|r| r.contains("pause")), "{regs:?}");
    }

    /// The json writer serializes non-finite floats as `null`; a null
    /// metric must fail the gate *closed* with a clear message — not parse
    /// as 0 (silent pass) and not panic.
    #[test]
    fn null_metrics_fail_closed_with_clear_message() {
        // serve: null requests_per_sec (the run's wall clock was NaN)
        let good = serve_doc(1000.0, 1500.0, 10.0, 8.0);
        let nulled = parse(
            r#"{"bench":"serve_throughput","policy":{},"results":[
                {"kind":"standard","concurrency":16,"requests_per_sec":null,
                 "metrics":{"request_p99_ms":10.0}},
                {"kind":"switchback","concurrency":16,"requests_per_sec":1500.0,
                 "metrics":{"request_p99_ms":8.0}}
            ]}"#,
        )
        .unwrap();
        let err = compare_bench(&good, &nulled, 0.15, false).unwrap_err();
        assert!(err.contains("null"), "{err}");
        assert!(err.contains("requests_per_sec"), "{err}");
        assert!(err.contains("non-finite"), "{err}");

        // train: final_loss null (diverged run wrote NaN)
        let tr = train_doc(3.4, 2.1, 12.0, 0, false);
        let nulled = parse(
            r#"{"bench":"train_native","config":{},"results":[
                {"kind":"switchback","optimizer":"stable_adamw",
                 "first_loss":3.4,"final_loss":null,
                 "steps_per_sec":12.0,"loss_spikes":0,"diverged":true}
            ]}"#,
        )
        .unwrap();
        let err = compare_bench(&tr, &nulled, 0.15, false).unwrap_err();
        assert!(err.contains("final_loss") && err.contains("null"), "{err}");

        // a null in the *baseline* is equally incomparable (strict path)
        let nulled_base = parse(
            r#"{"bench":"train_native","config":{},"results":[
                {"kind":"switchback","optimizer":"stable_adamw",
                 "first_loss":3.4,"final_loss":2.1,
                 "steps_per_sec":null,"loss_spikes":0,"diverged":false}
            ]}"#,
        )
        .unwrap();
        let err = compare_bench(&nulled_base, &tr, 0.15, true).unwrap_err();
        assert!(err.contains("steps_per_sec") && err.contains("null"), "{err}");

        // ckpt: null eval_acc
        let good_ck = ckpt_doc(0, true, 0.8, 100.0, 50.0);
        let nulled_ck = parse(
            r#"{"bench":"ckpt_pipeline","config":{},"results":[
                {"kind":"switchback","dropped_requests":0,
                 "round_trip_ok":true,"eval_matches_model":true,
                 "cache_invalidated":true,"weights_changed":true,
                 "eval_acc":null,"save_mb_s":100.0,"load_mb_s":100.0,
                 "hot_swap_pause_us":50.0}
            ]}"#,
        )
        .unwrap();
        let err = compare_bench(&good_ck, &nulled_ck, 0.15, false).unwrap_err();
        assert!(err.contains("eval_acc") && err.contains("null"), "{err}");
    }

    /// A serve doc with the plain standard/switchback pair plus one
    /// swap-aware entry (`swap_every` + standby counters).
    fn serve_doc_with_swap(
        errors: u64,
        promotions: u64,
        rejects: u64,
        swap_p99: f64,
    ) -> Value {
        parse(&format!(
            r#"{{"bench":"serve_throughput","policy":{{}},"results":[
                {{"kind":"standard","concurrency":16,"requests_per_sec":1000.0,
                  "errors":0,"metrics":{{"request_p99_ms":10.0}}}},
                {{"kind":"switchback","concurrency":16,"requests_per_sec":1500.0,
                  "errors":0,"metrics":{{"request_p99_ms":8.0}}}},
                {{"kind":"switchback","concurrency":16,"swap_every":250,
                  "requests_per_sec":1200.0,"errors":{errors},
                  "metrics":{{"request_p99_ms":{swap_p99},
                              "standby_promotions":{promotions},
                              "standby_rejects":{rejects},
                              "standby_rollbacks":0}}}}
            ]}}"#
        ))
        .unwrap()
    }

    /// Swap-aware entries are gated on invariants (zero errors, ≥1
    /// promotion, bounded tail vs the single-generation run) and are
    /// excluded from the plain throughput-ratio comparison.
    #[test]
    fn swap_entries_are_gated_on_invariants() {
        let old = serve_doc(1000.0, 1500.0, 10.0, 8.0); // no swap entry
        let good = serve_doc_with_swap(0, 3, 0, 12.0);
        let regs = compare_bench(&old, &good, 0.15, false).unwrap();
        assert!(regs.is_empty(), "{regs:?}");
        // the swap run must not poison the ratio math: identical ratios
        // pass even though a slower swap-mode entry exists for the same
        // (kind, concurrency)
        let regs = compare_bench(&good, &good, 0.15, false).unwrap();
        assert!(regs.is_empty(), "{regs:?}");

        let dropped = serve_doc_with_swap(4, 3, 0, 12.0);
        let regs = compare_bench(&old, &dropped, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("failed")), "{regs:?}");

        let unswapped = serve_doc_with_swap(0, 0, 0, 12.0);
        let regs = compare_bench(&old, &unswapped, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("promoted")), "{regs:?}");

        // a recorded reject means a promotion failed validation mid-run
        let rejected = serve_doc_with_swap(0, 3, 1, 12.0);
        let regs = compare_bench(&old, &rejected, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("validation")), "{regs:?}");

        // swap p99 more than SWAP_TAIL_FACTOR× the single-generation p99
        let stalled = serve_doc_with_swap(0, 3, 0, 8.0 * SWAP_TAIL_FACTOR + 1.0);
        let regs = compare_bench(&old, &stalled, 0.15, false).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("swap-tail-latency")),
            "{regs:?}"
        );

        // the swap entry disappearing from the fresh doc fails closed
        let err = compare_bench(&good, &old, 0.15, false).unwrap_err();
        assert!(err.contains("swap-every"), "{err}");
    }

    /// A serve doc with the plain standard/switchback pair plus one
    /// scraper-present entry (`scrape_every_ms` + rider stats).  The
    /// scrape run's `serve_p99` is the serving path's own tail while the
    /// rider scrapes (the SCRAPE_TAIL_FACTOR input).
    fn serve_doc_with_scrape(
        scrapes: u64,
        scrape_errors: u64,
        scrape_p99_us: f64,
        serve_p99: f64,
    ) -> Value {
        parse(&format!(
            r#"{{"bench":"serve_throughput","policy":{{}},"results":[
                {{"kind":"standard","concurrency":16,"requests_per_sec":1000.0,
                  "errors":0,"metrics":{{"request_p99_ms":10.0}}}},
                {{"kind":"switchback","concurrency":16,"requests_per_sec":1500.0,
                  "errors":0,"metrics":{{"request_p99_ms":8.0}}}},
                {{"kind":"switchback","concurrency":16,"scrape_every_ms":5,
                  "scrapes":{scrapes},"scrape_errors":{scrape_errors},
                  "scrape_p99_us":{scrape_p99_us},
                  "requests_per_sec":1400.0,"errors":0,
                  "metrics":{{"request_p99_ms":{serve_p99}}}}}
            ]}}"#
        ))
        .unwrap()
    }

    /// Scraper-present entries are gated on invariants (≥1 well-formed
    /// scrape, zero scrape errors, scrape p99 under the absolute budget,
    /// serve tail within SCRAPE_TAIL_FACTOR of the scraper-free run) and
    /// are excluded from the plain throughput-ratio comparison.
    #[test]
    fn scrape_entries_are_gated_on_invariants() {
        let old = serve_doc(1000.0, 1500.0, 10.0, 8.0); // no scrape entry
        let good = serve_doc_with_scrape(40, 0, 900.0, 9.0);
        let regs = compare_bench(&old, &good, 0.15, false).unwrap();
        assert!(regs.is_empty(), "{regs:?}");
        // the scrape run must not poison the ratio math: identical docs
        // pass even though a slower scrape-mode entry exists for the
        // same (kind, concurrency) — in portable and strict mode both
        let regs = compare_bench(&good, &good, 0.15, false).unwrap();
        assert!(regs.is_empty(), "{regs:?}");
        let regs = compare_bench(&good, &good, 0.15, true).unwrap();
        assert!(regs.is_empty(), "{regs:?}");

        // a scraper that never completed a scrape: caught
        let idle = serve_doc_with_scrape(0, 0, 0.0, 9.0);
        let regs = compare_bench(&old, &idle, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("no scrapes")), "{regs:?}");

        // failed / malformed scrapes: caught
        let torn = serve_doc_with_scrape(40, 2, 900.0, 9.0);
        let regs = compare_bench(&old, &torn, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("malformed")), "{regs:?}");

        // scrape p99 over the absolute budget: caught
        let slow = serve_doc_with_scrape(40, 0, SCRAPE_P99_BUDGET_US + 1.0, 9.0);
        let regs = compare_bench(&old, &slow, 0.15, false).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("µs budget")),
            "{regs:?}"
        );

        // the scraper moving the serve tail beyond the factor: caught
        let moved =
            serve_doc_with_scrape(40, 0, 900.0, 8.0 * SCRAPE_TAIL_FACTOR + 1.0);
        let regs = compare_bench(&old, &moved, 0.15, false).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("scrape-tail-latency")),
            "{regs:?}"
        );

        // the scrape entry disappearing from the fresh doc fails closed
        let err = compare_bench(&good, &old, 0.15, false).unwrap_err();
        assert!(err.contains("scrape-every"), "{err}");

        // a scrape entry missing its own stats is incomparable, not a
        // pass (fail closed on the declared-but-absent schema)
        let gutted = parse(
            r#"{"bench":"serve_throughput","policy":{},"results":[
                {"kind":"standard","concurrency":16,"requests_per_sec":1000.0,
                 "metrics":{"request_p99_ms":10.0}},
                {"kind":"switchback","concurrency":16,"requests_per_sec":1500.0,
                 "metrics":{"request_p99_ms":8.0}},
                {"kind":"switchback","concurrency":16,"scrape_every_ms":5,
                 "requests_per_sec":1400.0,
                 "metrics":{"request_p99_ms":9.0}}
            ]}"#,
        )
        .unwrap();
        let err = compare_bench(&good, &gutted, 0.15, false).unwrap_err();
        assert!(err.contains("scrapes"), "{err}");
    }

    /// A serve doc with the plain standard/switchback pair plus the two
    /// real-TCP entries `loadgen --socket` emits: a clean run at the base
    /// concurrency and an overload run at 4× with `overload:true`.
    fn serve_doc_with_socket(
        clean_errors: u64,
        clean_rejected: u64,
        clean_p99: f64,
        overload_rejected: u64,
    ) -> Value {
        parse(&format!(
            r#"{{"bench":"serve_throughput","policy":{{}},"results":[
                {{"kind":"standard","concurrency":16,"requests_per_sec":1000.0,
                  "errors":0,"metrics":{{"request_p99_ms":10.0}}}},
                {{"kind":"switchback","concurrency":16,"requests_per_sec":1500.0,
                  "errors":0,"metrics":{{"request_p99_ms":8.0}}}},
                {{"kind":"switchback","concurrency":16,"socket":true,
                  "requests_per_sec":900.0,"errors":{clean_errors},
                  "metrics":{{"request_p99_ms":{clean_p99},
                              "rejected":{clean_rejected}}}}},
                {{"kind":"switchback","concurrency":64,"socket":true,
                  "overload":true,"requests_per_sec":700.0,"errors":0,
                  "metrics":{{"request_p99_ms":40.0,
                              "rejected":{overload_rejected}}}}}
            ]}}"#
        ))
        .unwrap()
    }

    /// Socket entries are gated on invariants (zero request errors, the
    /// clean run sheds nothing, the overload run records ≥1 admission
    /// rejection, socket tail within SOCKET_TAIL_FACTOR of the in-process
    /// run) and are excluded from the plain throughput-ratio comparison.
    #[test]
    fn socket_entries_are_gated_on_invariants() {
        let old = serve_doc(1000.0, 1500.0, 10.0, 8.0); // no socket entries
        let good = serve_doc_with_socket(0, 0, 12.0, 37);
        let regs = compare_bench(&old, &good, 0.15, false).unwrap();
        assert!(regs.is_empty(), "{regs:?}");
        // the socket runs must not poison the ratio math: identical docs
        // pass even though slower socket entries exist for switchback —
        // in portable and strict mode both
        let regs = compare_bench(&good, &good, 0.15, false).unwrap();
        assert!(regs.is_empty(), "{regs:?}");
        let regs = compare_bench(&good, &good, 0.15, true).unwrap();
        assert!(regs.is_empty(), "{regs:?}");

        // requests failing through the front door: caught
        let broken = serve_doc_with_socket(3, 0, 12.0, 37);
        let regs = compare_bench(&old, &broken, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("front door")), "{regs:?}");

        // the clean run shedding under the admission window: caught
        let shed = serve_doc_with_socket(0, 5, 12.0, 37);
        let regs = compare_bench(&old, &shed, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("must not overload")), "{regs:?}");

        // an overload run that never got rejected: caught
        let lax = serve_doc_with_socket(0, 0, 12.0, 0);
        let regs = compare_bench(&old, &lax, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("429")), "{regs:?}");

        // socket p99 more than SOCKET_TAIL_FACTOR× the in-process p99
        let queueing = serve_doc_with_socket(0, 0, 8.0 * SOCKET_TAIL_FACTOR + 1.0, 37);
        let regs = compare_bench(&old, &queueing, 0.15, false).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("socket-tail-latency")),
            "{regs:?}"
        );

        // either socket entry disappearing from the fresh doc fails closed
        let err = compare_bench(&good, &old, 0.15, false).unwrap_err();
        assert!(err.contains("socket"), "{err}");

        // a socket entry with no in-process anchor cannot prove its tail
        // bound — flagged, not silently passed
        let unanchored = parse(
            r#"{"bench":"serve_throughput","policy":{},"results":[
                {"kind":"standard","concurrency":16,"requests_per_sec":1000.0,
                 "metrics":{"request_p99_ms":10.0}},
                {"kind":"switchback","concurrency":16,"requests_per_sec":1500.0,
                 "metrics":{"request_p99_ms":8.0}},
                {"kind":"switchback","concurrency":32,"socket":true,
                 "requests_per_sec":900.0,"errors":0,
                 "metrics":{"request_p99_ms":12.0,"rejected":0}}
            ]}"#,
        )
        .unwrap();
        let regs = compare_bench(&old, &unanchored, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("no in-process entry")), "{regs:?}");

        // a socket entry missing its own ledger is incomparable, not a
        // pass (fail closed on the declared-but-absent schema)
        let gutted = parse(
            r#"{"bench":"serve_throughput","policy":{},"results":[
                {"kind":"standard","concurrency":16,"requests_per_sec":1000.0,
                 "metrics":{"request_p99_ms":10.0}},
                {"kind":"switchback","concurrency":16,"requests_per_sec":1500.0,
                 "metrics":{"request_p99_ms":8.0}},
                {"kind":"switchback","concurrency":16,"socket":true,
                 "requests_per_sec":900.0,"errors":0,
                 "metrics":{"request_p99_ms":12.0}}
            ]}"#,
        )
        .unwrap();
        let err = compare_bench(&good, &gutted, 0.15, false).unwrap_err();
        assert!(err.contains("rejected"), "{err}");
    }

    /// Ckpt standby counters gate: rollbacks are never expected, and the
    /// promote/reject counts must not shrink vs the baseline scenario.
    #[test]
    fn ckpt_standby_counters_are_gated() {
        let with_standby = |promos: u64, rejects: u64, rollbacks: u64| -> Value {
            parse(&format!(
                r#"{{"bench":"ckpt_pipeline","config":{{}},"results":[
                    {{"kind":"switchback","dropped_requests":0,
                      "round_trip_ok":true,"eval_matches_model":true,
                      "cache_invalidated":true,"weights_changed":true,
                      "eval_acc":0.8,"save_mb_s":100.0,"load_mb_s":100.0,
                      "hot_swap_pause_us":50.0,
                      "standby_promotions":{promos},
                      "standby_rejects":{rejects},
                      "standby_rollbacks":{rollbacks}}}
                ]}}"#
            ))
            .unwrap()
        };
        let base = with_standby(3, 1, 0);
        assert!(compare_bench(&base, &base, 0.15, false).unwrap().is_empty());
        // an old baseline without the counters still compares cleanly
        let old_schema = ckpt_doc(0, true, 0.8, 100.0, 50.0);
        assert!(compare_bench(&old_schema, &base, 0.15, false)
            .unwrap()
            .is_empty());

        let rolled = with_standby(3, 1, 2);
        let regs = compare_bench(&base, &rolled, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("rollback")), "{regs:?}");

        let fewer_promos = with_standby(1, 1, 0);
        let regs = compare_bench(&base, &fewer_promos, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("promotions")), "{regs:?}");

        let no_reject = with_standby(3, 0, 0);
        let regs = compare_bench(&base, &no_reject, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("rejections")), "{regs:?}");

        // counters vanishing from the fresh run fail closed too
        let regs = compare_bench(&base, &old_schema, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("omits")), "{regs:?}");
    }

    /// A ckpt entry carrying the v2 shard fields: the sharded-snapshot
    /// invariants gate bit-identity, quarantines, shard-count shrinkage,
    /// and the fields vanishing — and strict gates the shard MB/s.
    fn ckpt_doc_sharded(
        identical: bool,
        quarantines: u64,
        shards: u64,
        shard_save: f64,
    ) -> Value {
        parse(&format!(
            r#"{{"bench":"ckpt_pipeline","config":{{}},"results":[
                {{"kind":"switchback","dropped_requests":0,
                  "round_trip_ok":true,"eval_matches_model":true,
                  "cache_invalidated":true,"weights_changed":true,
                  "eval_acc":0.8,"save_mb_s":100.0,"load_mb_s":100.0,
                  "ckpt_shards":{shards},"shard_save_mb_s":{shard_save},
                  "shard_load_mb_s":{shard_save},
                  "sharded_bit_identical":{identical},
                  "standby_quarantines":{quarantines},
                  "hot_swap_pause_us":50.0}}
            ]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn ckpt_shard_invariants_are_gated() {
        let base = ckpt_doc_sharded(true, 0, 4, 200.0);
        assert!(compare_bench(&base, &base, 0.15, false).unwrap().is_empty());
        // an old baseline without shard fields still compares cleanly
        let old_schema = ckpt_doc(0, true, 0.8, 100.0, 50.0);
        assert!(compare_bench(&old_schema, &base, 0.15, false)
            .unwrap()
            .is_empty());

        // bit-identity broken: caught portably
        let broken = ckpt_doc_sharded(false, 0, 4, 200.0);
        let regs = compare_bench(&base, &broken, 0.15, false).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("sharded_bit_identical")),
            "{regs:?}"
        );

        // a quarantined snapshot: caught portably
        let quarantined = ckpt_doc_sharded(true, 2, 4, 200.0);
        let regs = compare_bench(&base, &quarantined, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("quarantined")), "{regs:?}");

        // shard count shrank vs the baseline scenario: caught
        let fewer = ckpt_doc_sharded(true, 0, 1, 200.0);
        let regs = compare_bench(&base, &fewer, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("shard count")), "{regs:?}");

        // the shard fields vanishing from a fresh run fails closed
        let regs = compare_bench(&base, &old_schema, 0.15, false).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("omits")),
            "shard metrics absence must not read as a pass: {regs:?}"
        );

        // shard MB/s is a machine absolute: portable ignores a collapse,
        // strict catches it
        let slow = ckpt_doc_sharded(true, 0, 4, 20.0);
        assert!(compare_bench(&base, &slow, 0.15, false).unwrap().is_empty());
        let regs = compare_bench(&base, &slow, 0.15, true).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("shard_save_mb_s")),
            "{regs:?}"
        );
    }

    #[test]
    fn mismatched_and_malformed_docs_error() {
        let serve = serve_doc(1.0, 1.0, 1.0, 1.0);
        let train = train_doc(3.0, 2.0, 1.0, 0, false);
        assert!(compare_bench(&serve, &train, 0.15, false).is_err());
        let junk = parse(r#"{"bench":"nope","results":[]}"#).unwrap();
        assert!(compare_bench(&junk, &junk, 0.15, false).is_err());
        let nores = parse(r#"{"bench":"train_native"}"#).unwrap();
        assert!(compare_bench(&nores, &nores, 0.15, false).is_err());
    }

    /// One gemm_kernels shape entry; speedups derive from the ms fields
    /// the way the bench computes them.
    fn gemm_shape(
        b: usize,
        k: usize,
        m: usize,
        f32_ms: f64,
        reference_ms: f64,
        blocked_ms: f64,
    ) -> String {
        format!(
            r#"{{"name":"b{b}_k{k}_m{m}","b":{b},"k":{k},"m":{m},
                "f32_ms":{f32_ms},"reference_ms":{reference_ms},
                "blocked_ms":{blocked_ms},
                "blocked_speedup":{speedup},
                "int8_vs_f32":{vs_f32}}}"#,
            speedup = reference_ms / blocked_ms,
            vs_f32 = f32_ms / blocked_ms,
        )
    }

    fn gemm_doc(shapes: &[String], quant: Option<&str>) -> Value {
        let qf = match quant {
            Some(q) => format!(r#","quant_fraction":{q}"#),
            None => String::new(),
        };
        parse(&format!(
            r#"{{"bench":"gemm_kernels","isa":"avx2","threads":8,
                "results":[{}]{qf}}}"#,
            shapes.join(",")
        ))
        .unwrap()
    }

    fn gemm_base_shapes(scale: f64) -> Vec<String> {
        // blocked ~1.6× the flat reference at every shape; `scale` models
        // machine speed (same ratios, different absolutes)
        vec![
            gemm_shape(256, 256, 256, 20.0 * scale, 8.0 * scale, 5.0 * scale),
            gemm_shape(512, 128, 512, 40.0 * scale, 16.0 * scale, 10.0 * scale),
            gemm_shape(512, 512, 512, 80.0 * scale, 32.0 * scale, 20.0 * scale),
        ]
    }

    const GEMM_QF: &str = r#"[
        {"dim":128,"quant_ms":0.5,"matmul_ms":2.0,"quant_pct":20.0},
        {"dim":256,"quant_ms":1.5,"matmul_ms":10.0,"quant_pct":13.0}]"#;

    #[test]
    fn portable_gemm_passes_across_machines() {
        // same kernel ratios at 4× different machine speed: no regression
        let old = gemm_doc(&gemm_base_shapes(1.0), Some(GEMM_QF));
        let new = gemm_doc(&gemm_base_shapes(4.0), Some(GEMM_QF));
        let regs = compare_bench(&old, &new, 0.15, false).unwrap();
        assert!(regs.is_empty(), "{regs:?}");
        // strict flags the absolute collapse
        let regs = compare_bench(&old, &new, 0.15, true).unwrap();
        assert!(regs.iter().any(|r| r.contains("blocked_ms")), "{regs:?}");
    }

    #[test]
    fn gemm_speedup_curve_regression_is_caught() {
        let old = gemm_doc(&gemm_base_shapes(1.0), None);
        // largest shape's blocked kernel lost its edge: 32/20 → 32/30
        let mut shapes = gemm_base_shapes(1.0);
        shapes[2] = gemm_shape(512, 512, 512, 80.0, 32.0, 30.0);
        let new = gemm_doc(&shapes, None);
        let regs = compare_bench(&old, &new, 0.15, false).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("speedup fell")),
            "{regs:?}"
        );
    }

    #[test]
    fn gemm_blocked_slower_than_reference_is_caught() {
        // even against a baseline that agrees, blocked < reference at a
        // largest shape trips the floor gate
        let mut shapes = gemm_base_shapes(1.0);
        shapes[2] = gemm_shape(512, 512, 512, 80.0, 32.0, 40.0); // 0.8×
        let doc = gemm_doc(&shapes, None);
        let regs = compare_bench(&doc, &doc, 0.15, false).unwrap();
        assert!(
            regs.iter().any(|r| r.contains("slower than the flat reference")),
            "{regs:?}"
        );
    }

    #[test]
    fn gemm_missing_shape_and_vanished_quant_fraction_fail_closed() {
        let old = gemm_doc(&gemm_base_shapes(1.0), Some(GEMM_QF));
        // a baseline shape disappearing from the new doc is an error,
        // not a pass
        let fewer = gemm_doc(&gemm_base_shapes(1.0)[..2].to_vec(), Some(GEMM_QF));
        assert!(compare_bench(&old, &fewer, 0.15, false).is_err());
        // the quant_fraction block vanishing is an error too
        let noq = gemm_doc(&gemm_base_shapes(1.0), None);
        assert!(compare_bench(&old, &noq, 0.15, false).is_err());
        // ... but a baseline that never had it compares cleanly
        assert!(compare_bench(&noq, &noq, 0.15, false).unwrap().is_empty());
    }

    #[test]
    fn gemm_quant_fraction_ceiling_and_null_metrics() {
        let old = gemm_doc(&gemm_base_shapes(1.0), Some(GEMM_QF));
        // quantize eating >50% at the largest dim: caught portably
        let hot = r#"[{"dim":128,"quant_ms":0.5,"matmul_ms":2.0,"quant_pct":20.0},
            {"dim":256,"quant_ms":30.0,"matmul_ms":10.0,"quant_pct":75.0}]"#;
        let new = gemm_doc(&gemm_base_shapes(1.0), Some(hot));
        let regs = compare_bench(&old, &new, 0.15, false).unwrap();
        assert!(regs.iter().any(|r| r.contains("quantize fraction")), "{regs:?}");
        // a null metric fails closed rather than comparing as 0
        let nulled = parse(
            r#"{"bench":"gemm_kernels","results":[
                {"name":"b256_k256_m256","b":256,"k":256,"m":256,
                 "f32_ms":20.0,"reference_ms":8.0,"blocked_ms":null,
                 "blocked_speedup":1.6,"int8_vs_f32":4.0}]}"#,
        )
        .unwrap();
        assert!(compare_bench(&nulled, &nulled, 0.15, false).is_err());
    }

    #[test]
    fn gemm_strict_gates_quant_fraction_absolutes() {
        let old = gemm_doc(&gemm_base_shapes(1.0), Some(GEMM_QF));
        let slow_q = r#"[
            {"dim":128,"quant_ms":0.5,"matmul_ms":2.0,"quant_pct":20.0},
            {"dim":256,"quant_ms":4.5,"matmul_ms":10.0,"quant_pct":31.0}]"#;
        let new = gemm_doc(&gemm_base_shapes(1.0), Some(slow_q));
        // portable: under the ceiling, no complaint
        assert!(compare_bench(&old, &new, 0.15, false).unwrap().is_empty());
        // strict: the 3× quant_ms rise at dim 256 is caught
        let regs = compare_bench(&old, &new, 0.15, true).unwrap();
        assert!(regs.iter().any(|r| r.contains("quant_ms")), "{regs:?}");
    }
}
