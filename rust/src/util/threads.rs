//! Data-parallel helper: split a mutable slice into contiguous chunks and
//! process them on scoped threads (the GEMM/optimizer thread pool).
//!
//! `std::thread::scope` keeps this dependency-free; threads are spawned per
//! call, which costs ~10µs each — negligible against the ≥1ms GEMMs this
//! parallelizes (measured in EXPERIMENTS.md §Perf).

/// Serializes unit tests that set the process-global `SWITCHBACK_THREADS`
/// env var (cargo runs tests on parallel threads; two writers would race).
/// Lock it around any `ThreadsEnvGuard`-style override.
#[cfg(test)]
pub(crate) static THREADS_ENV_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Number of worker threads (cores, capped; override with SWITCHBACK_THREADS).
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("SWITCHBACK_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(32)
}

/// Process `data` in contiguous chunks of `chunk_rows * row_len` elements,
/// calling `f(first_row_index, rows_chunk)` in parallel.
///
/// `f` must be pure per chunk (no cross-chunk communication).
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], row_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    if row_len == 0 || data.is_empty() {
        return;
    }
    let n_rows = data.len() / row_len;
    let workers = num_threads().min(n_rows.max(1));
    if workers <= 1 || n_rows <= 1 {
        f(0, data);
        return;
    }
    let rows_per = n_rows.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut row0 = 0usize;
        let fref = &f;
        while !rest.is_empty() {
            let take = (rows_per * row_len).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            rest = tail;
            let my_row0 = row0;
            row0 += take / row_len;
            s.spawn(move || fref(my_row0, chunk));
        }
    });
}

/// Parallel map over indices `0..n` collecting results in order.
pub fn par_map<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(workers);
    std::thread::scope(|s| {
        let fref = &f;
        let mut rest: &mut [Option<R>] = &mut out;
        let mut start = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            rest = tail;
            let my_start = start;
            start += take;
            s.spawn(move || {
                for (i, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(fref(my_start + i));
                }
            });
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

/// Fallible [`par_map`]: run `f` over `0..n` in parallel and collect the
/// results, returning the lowest-index error if any call failed.  Every
/// call still runs (scoped threads cannot abort siblings mid-flight); the
/// deterministic index-order error pick keeps failures reproducible
/// across thread counts.  Used by the sharded checkpoint reader/writer
/// (`ckpt::format`), where each shard's I/O + CRC runs on its own worker.
pub fn par_try_map<R: Send, E: Send, F>(n: usize, f: F) -> Result<Vec<R>, E>
where
    F: Fn(usize) -> Result<R, E> + Sync,
{
    par_map(n, f).into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_all_rows_once() {
        let mut data = vec![0u32; 103 * 7];
        par_chunks_mut(&mut data, 7, |row0, chunk| {
            for (r, row) in chunk.chunks_mut(7).enumerate() {
                for v in row.iter_mut() {
                    *v = (row0 + r) as u32;
                }
            }
        });
        for (r, row) in data.chunks(7).enumerate() {
            assert!(row.iter().all(|&v| v == r as u32), "row {r}");
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(1000, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut e: Vec<u32> = vec![];
        par_chunks_mut(&mut e, 4, |_, _| panic!("must not be called"));
        let out: Vec<usize> = par_map(1, |i| i);
        assert_eq!(out, vec![0]);
    }

    #[test]
    fn par_try_map_collects_or_fails_deterministically() {
        let ok: Result<Vec<usize>, String> = par_try_map(100, |i| Ok(i * 2));
        assert_eq!(ok.unwrap()[99], 198);
        // multiple failures: the lowest index wins regardless of which
        // worker finished first
        let err: Result<Vec<usize>, String> =
            par_try_map(100, |i| if i % 7 == 3 { Err(format!("bad {i}")) } else { Ok(i) });
        assert_eq!(err.unwrap_err(), "bad 3");
        let none: Result<Vec<usize>, String> = par_try_map(0, |_| Err("x".into()));
        assert!(none.unwrap().is_empty());
    }
}
