//! Packed cache-blocked int8 GEMM — the production kernel behind
//! [`crate::gemm::MatmulPlan`] and every int8 linear layer.
//!
//! The reference kernels in `i8mm.rs` walk `w.codes` row-major per output
//! element; at serving shapes the weight panel falls out of L1 between
//! activation rows and every dot re-streams it.  This module fixes that
//! with the classic three-level blocking, sized for this CPU:
//!
//! * **Panel packing** (prepare time, once per weight): weight rows are
//!   grouped into panels of [`MR`] rows, and within a panel the codes are
//!   interleaved in [`KP`]-byte column chunks — `data[((p·kblocks + kb)·MR
//!   + r)·KP + c]` holds row `p·MR+r`, column `kb·KP+c`.  The micro-kernel
//!   therefore reads the panel *exactly sequentially*.  Both `k` and `m`
//!   are zero-padded to the tile grid; zero codes contribute nothing to an
//!   integer accumulation, so padding never changes a result.
//! * **Cache blocking** (run time): activations are processed [`RB`] rows
//!   at a time with the panel loop outside the row loop, so one panel
//!   (`MR·k` bytes, L1-resident) is reused across all `RB` rows before the
//!   next panel streams in — weight traffic from L2/memory drops by `RB`×.
//!   Activation codes are sign-extended to i16 once per row block
//!   (amortized over `m/MR` panel passes), which feeds `pmaddwd` directly.
//! * **Micro-kernel** (`std::arch` SIMD): `_mm_madd_epi16` (SSE2, baseline
//!   for every x86_64) or `_mm256_madd_epi16` (AVX2, runtime-detected)
//!   accumulate i8×i8 products into i32 lanes.  The scalar loop — same
//!   shape as the reference `dot_i8` — is the portable fallback and the
//!   oracle the SIMD paths are tested against.  Integer adds are
//!   associative, so every variant produces **bit-identical** i32
//!   accumulators, and the shared f32 epilogue keeps the packed results
//!   bit-identical to the reference GEMMs (the `nn` train/infer parity
//!   tests depend on this).
//!
//! The epilogue can optionally apply an elementwise map (gelu) and
//! re-quantize each finished row ([`gemm_i8_packed_fused`]), handing the
//! *next* layer its row-quantized input directly — inter-layer activations
//! never round-trip f32 through memory (the Scalify-style scale
//! propagation the ROADMAP calls for).

use crate::quant::{
    quantize_one, quantize_row_into, safe_absmax, QuantScheme, QuantizedRow,
    QuantizedTensor, INT8_MAX,
};
use crate::tensor::{Matrix, MatrixI8};
use crate::util::threads::num_threads;

/// Panel height: weight rows packed together and produced per micro-kernel
/// call (8 i32 accumulators stay in registers on SSE2 and AVX2).
pub const MR: usize = 8;

/// Packed k-step in codes: one 128-bit SIMD register of i8.
pub const KP: usize = 16;

/// Activation rows per cache block: one packed panel stays L1-hot across
/// this many rows before the next panel streams in.
const RB: usize = 8;

/// Dequantization state carried by a packed weight.
#[derive(Debug, Clone)]
pub enum PackedScale {
    /// tensor-wise: one absmax for the whole weight (SwitchBack).
    Tensor(f32),
    /// row-wise-per-output: absmax per logical weight row (LLM.int8()).
    Row(Vec<f32>),
}

/// A weight quantized to int8 and packed into the blocked kernel's
/// tile-major panel layout (see the module docs), built once at
/// prepare/load time.
#[derive(Debug, Clone)]
pub struct PackedInt8 {
    /// logical weight rows (= output features)
    pub m: usize,
    /// logical inner dim (= input features)
    pub k: usize,
    /// `ceil(k / KP)` column chunks per panel row
    kblocks: usize,
    /// `ceil(m / MR)` panels
    panels: usize,
    /// `panels · kblocks · MR · KP` codes, tile-major, zero-padded
    data: Vec<i8>,
    pub scale: PackedScale,
}

impl PackedInt8 {
    /// Quantize `w` under `scheme` and pack it in one pass (no
    /// intermediate code matrix is materialized).
    pub fn quantize(scheme: QuantScheme, w: &Matrix) -> Self {
        match scheme {
            QuantScheme::TensorWise => Self::quantize_tensorwise(w),
            QuantScheme::TensorWiseTranspose => {
                Self::quantize_tensorwise_transpose(w)
            }
            QuantScheme::RowWise => Self::quantize_rowwise(w),
            QuantScheme::ColWise => {
                panic!("packed GEMM has no col-wise weight form")
            }
        }
    }

    fn grid(m: usize, k: usize) -> (usize, usize, Vec<i8>) {
        let kblocks = k.div_ceil(KP).max(1);
        let panels = m.div_ceil(MR).max(1);
        (kblocks, panels, vec![0i8; panels * kblocks * MR * KP])
    }

    /// Fused tensor-wise quantize + pack (paper eq. 2 → panel layout).
    pub fn quantize_tensorwise(w: &Matrix) -> Self {
        let state =
            safe_absmax(w.data.iter().fold(0.0f32, |m, &v| m.max(v.abs())));
        let scale = INT8_MAX / state;
        let (m, k) = (w.rows, w.cols);
        let (kblocks, panels, mut data) = Self::grid(m, k);
        for p in 0..panels {
            for r in 0..MR.min(m - (p * MR).min(m)) {
                let src = w.row(p * MR + r);
                for kb in 0..kblocks {
                    let c0 = kb * KP;
                    let n = KP.min(k - c0.min(k));
                    let dst0 = ((p * kblocks + kb) * MR + r) * KP;
                    for i in 0..n {
                        data[dst0 + i] = quantize_one(src[c0 + i], scale);
                    }
                }
            }
        }
        Self { m, k, kblocks, panels, data, scale: PackedScale::Tensor(state) }
    }

    /// Tensor-wise quantize + **transpose** + pack: the packed matrix is
    /// `wᵀ`.  Routes through the public fused quantize+transpose
    /// (`tensorwise_quant_transpose`, paper §2.2.1) — `wᵀ` codes are
    /// produced in one blocked pass over `w` without materializing `wᵀ`
    /// in f32 — then the exact panel re-layout.  This is the int8 dgrad's
    /// weight-prepare step ([`super::MatmulPlan::dgrad`]).
    pub fn quantize_tensorwise_transpose(w: &Matrix) -> Self {
        let q = crate::quant::tensorwise_quant_transpose(w);
        Self::pack_tensorwise(&q)
    }

    /// Fused row-wise quantize + pack (per-output-row state, eq. 1).
    pub fn quantize_rowwise(w: &Matrix) -> Self {
        let (m, k) = (w.rows, w.cols);
        let mut state = vec![0.0f32; m];
        let (kblocks, panels, mut data) = Self::grid(m, k);
        for p in 0..panels {
            for r in 0..MR.min(m - (p * MR).min(m)) {
                let row = p * MR + r;
                let src = w.row(row);
                let mx = safe_absmax(
                    src.iter().fold(0.0f32, |m, &v| m.max(v.abs())),
                );
                state[row] = mx;
                let scale = INT8_MAX / mx;
                for kb in 0..kblocks {
                    let c0 = kb * KP;
                    let n = KP.min(k - c0);
                    let dst0 = ((p * kblocks + kb) * MR + r) * KP;
                    for i in 0..n {
                        data[dst0 + i] = quantize_one(src[c0 + i], scale);
                    }
                }
            }
        }
        Self { m, k, kblocks, panels, data, scale: PackedScale::Row(state) }
    }

    /// Pack already-quantized tensor-wise codes (exact re-layout).
    pub fn pack_tensorwise(q: &QuantizedTensor) -> Self {
        let (kblocks, panels, data) = pack_codes(&q.codes);
        Self {
            m: q.codes.rows,
            k: q.codes.cols,
            kblocks,
            panels,
            data,
            scale: PackedScale::Tensor(q.state),
        }
    }

    /// Pack already-quantized row-wise codes (exact re-layout).
    pub fn pack_rowwise(q: &QuantizedRow) -> Self {
        let (kblocks, panels, data) = pack_codes(&q.codes);
        Self {
            m: q.codes.rows,
            k: q.codes.cols,
            kblocks,
            panels,
            data,
            scale: PackedScale::Row(q.state.clone()),
        }
    }

    /// Resident bytes (packed codes + state) — the serve-memory metric.
    pub fn bytes(&self) -> usize {
        self.data.len()
            + match &self.scale {
                PackedScale::Tensor(_) => 4,
                PackedScale::Row(s) => s.len() * 4,
            }
    }
}

fn pack_codes(codes: &MatrixI8) -> (usize, usize, Vec<i8>) {
    let (m, k) = (codes.rows, codes.cols);
    let (kblocks, panels, mut data) = PackedInt8::grid(m, k);
    for p in 0..panels {
        for r in 0..MR.min(m - (p * MR).min(m)) {
            let src = codes.row(p * MR + r);
            for kb in 0..kblocks {
                let c0 = kb * KP;
                let n = KP.min(k - c0);
                let dst0 = ((p * kblocks + kb) * MR + r) * KP;
                data[dst0..dst0 + n].copy_from_slice(&src[c0..c0 + n]);
            }
        }
    }
    (kblocks, panels, data)
}

// ----- micro-kernels ---------------------------------------------------

/// Which inner-kernel instruction set the packed GEMM runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelIsa {
    /// portable fallback — also the oracle the SIMD paths test against
    Scalar,
    /// `_mm_madd_epi16` (baseline on every x86_64)
    Sse2,
    /// `_mm256_madd_epi16` (runtime-detected)
    Avx2,
}

impl KernelIsa {
    pub fn label(&self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Sse2 => "sse2",
            Self::Avx2 => "avx2",
        }
    }
}

/// Best micro-kernel available on this machine.
pub fn kernel_isa() -> KernelIsa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            KernelIsa::Avx2
        } else {
            KernelIsa::Sse2
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        KernelIsa::Scalar
    }
}

/// Portable panel micro-kernel: `acc[r] += dot(x16, panel row r)`.
/// `x16` is the sign-extended, zero-padded activation row
/// (`kblocks·KP` i16); `panel` is one packed panel (`kblocks·MR·KP` i8).
fn panel_dots_scalar(x16: &[i16], panel: &[i8], acc: &mut [i32; MR]) {
    for (kb, xc) in x16.chunks_exact(KP).enumerate() {
        let base = kb * MR * KP;
        for r in 0..MR {
            let wc = &panel[base + r * KP..base + (r + 1) * KP];
            let mut s = 0i32;
            for l in 0..KP {
                s += xc[l] as i32 * wc[l] as i32;
            }
            acc[r] += s;
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{KP, MR};
    use std::arch::x86_64::*;

    /// Sign-extend the low 8 i8 lanes to i16 (unpack-with-self then
    /// arithmetic shift — the SSE2 idiom; no SSE4.1 `pmovsx` needed).
    // SAFETY: register-only SSE2 intrinsics, baseline on x86_64; no
    // pointers are dereferenced.
    #[inline(always)]
    unsafe fn sext_lo(v: __m128i) -> __m128i {
        _mm_srai_epi16(_mm_unpacklo_epi8(v, v), 8)
    }

    // SAFETY: register-only SSE2 intrinsics, baseline on x86_64; no
    // pointers are dereferenced.
    #[inline(always)]
    unsafe fn sext_hi(v: __m128i) -> __m128i {
        _mm_srai_epi16(_mm_unpackhi_epi8(v, v), 8)
    }

    // SAFETY: register-only SSE2 intrinsics, baseline on x86_64; no
    // pointers are dereferenced.
    #[inline(always)]
    unsafe fn hsum(v: __m128i) -> i32 {
        let s = _mm_add_epi32(v, _mm_shuffle_epi32(v, 0b0100_1110));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b1011_0001));
        _mm_cvtsi128_si32(s)
    }

    /// SSE2 micro-kernel: 16 codes × MR rows per iteration via `pmaddwd`
    /// (i16 products pair-summed into i32 lanes — exact, no saturation:
    /// |codes| ≤ 127 so a pair sum is ≤ 2·127² ≪ 2³¹).
    // SAFETY: caller must pass `x16.len()` a multiple of KP and
    // `panel.len() == (x16.len()/KP)·MR·KP`; every unaligned load below
    // then stays in bounds.  SSE2 is baseline on x86_64.
    pub unsafe fn panel_dots_sse2(x16: &[i16], panel: &[i8], acc: &mut [i32; MR]) {
        let kblocks = x16.len() / KP;
        debug_assert_eq!(panel.len(), kblocks * MR * KP);
        let xp = x16.as_ptr();
        let pp = panel.as_ptr();
        let mut vacc = [_mm_setzero_si128(); MR];
        for kb in 0..kblocks {
            let xlo = _mm_loadu_si128(xp.add(kb * KP) as *const __m128i);
            let xhi = _mm_loadu_si128(xp.add(kb * KP + 8) as *const __m128i);
            let base = kb * MR * KP;
            for r in 0..MR {
                let wv = _mm_loadu_si128(pp.add(base + r * KP) as *const __m128i);
                let prod = _mm_add_epi32(
                    _mm_madd_epi16(xlo, sext_lo(wv)),
                    _mm_madd_epi16(xhi, sext_hi(wv)),
                );
                vacc[r] = _mm_add_epi32(vacc[r], prod);
            }
        }
        for r in 0..MR {
            acc[r] += hsum(vacc[r]);
        }
    }

    /// AVX2 micro-kernel: same tile, one `vpmaddwd` per 16 codes.
    // SAFETY: same slice-shape contract as `panel_dots_sse2`, and the
    // caller must have verified AVX2 support at runtime first.
    #[target_feature(enable = "avx2")]
    pub unsafe fn panel_dots_avx2(x16: &[i16], panel: &[i8], acc: &mut [i32; MR]) {
        let kblocks = x16.len() / KP;
        debug_assert_eq!(panel.len(), kblocks * MR * KP);
        let xp = x16.as_ptr();
        let pp = panel.as_ptr();
        let mut vacc = [_mm256_setzero_si256(); MR];
        for kb in 0..kblocks {
            let xv = _mm256_loadu_si256(xp.add(kb * KP) as *const __m256i);
            let base = kb * MR * KP;
            for r in 0..MR {
                let wb = _mm_loadu_si128(pp.add(base + r * KP) as *const __m128i);
                let wv = _mm256_cvtepi8_epi16(wb);
                vacc[r] = _mm256_add_epi32(vacc[r], _mm256_madd_epi16(xv, wv));
            }
        }
        for r in 0..MR {
            let lo = _mm256_castsi256_si128(vacc[r]);
            let hi = _mm256_extracti128_si256(vacc[r], 1);
            acc[r] += hsum(_mm_add_epi32(lo, hi));
        }
    }
}

#[inline]
fn panel_dots(isa: KernelIsa, x16: &[i16], panel: &[i8], acc: &mut [i32; MR]) {
    match isa {
        KernelIsa::Scalar => panel_dots_scalar(x16, panel, acc),
        // SAFETY: `dots_rows` slices x16/panel to the packed layout the
        // micro-kernels require; SSE2 is baseline on x86_64.
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Sse2 => unsafe { x86::panel_dots_sse2(x16, panel, acc) },
        // SAFETY: same shape contract as above, and KernelIsa::Avx2 is
        // only ever constructed after `is_x86_feature_detected!("avx2")`.
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => unsafe { x86::panel_dots_avx2(x16, panel, acc) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => panel_dots_scalar(x16, panel, acc),
    }
}

// ----- blocked driver --------------------------------------------------

/// Run the blocked kernel over activation rows `row0..row0+nrows`,
/// handing each finished row of raw i32 accumulators to `emit`.
fn dots_rows(
    isa: KernelIsa,
    x: &QuantizedRow,
    w: &PackedInt8,
    row0: usize,
    nrows: usize,
    mut emit: impl FnMut(usize, &[i32]),
) {
    let k = x.codes.cols;
    debug_assert_eq!(k, w.k, "inner dims disagree");
    let kpad = w.kblocks * KP;
    let panel_len = w.kblocks * MR * KP;
    let mut x16 = vec![0i16; RB * kpad];
    let mut acc = vec![0i32; RB * w.m];
    for c0 in (0..nrows).step_by(RB) {
        let rb = RB.min(nrows - c0);
        // sign-extend this block's activation codes once, zero-padded to
        // the packed k grid (zero codes add nothing — exactness preserved)
        for ri in 0..rb {
            let src = x.codes.row(row0 + c0 + ri);
            let dst = &mut x16[ri * kpad..(ri + 1) * kpad];
            for (d, &v) in dst.iter_mut().zip(src) {
                *d = v as i16;
            }
            for d in dst[k..].iter_mut() {
                *d = 0;
            }
        }
        // panel loop outside the row loop: one panel stays L1-hot across
        // all rb rows (the cache-blocking that beats the reference kernel)
        for p in 0..w.panels {
            let panel = &w.data[p * panel_len..(p + 1) * panel_len];
            let col0 = p * MR;
            let mr = MR.min(w.m - col0);
            for ri in 0..rb {
                let mut a = [0i32; MR];
                panel_dots(isa, &x16[ri * kpad..(ri + 1) * kpad], panel, &mut a);
                acc[ri * w.m + col0..ri * w.m + col0 + mr]
                    .copy_from_slice(&a[..mr]);
            }
        }
        for ri in 0..rb {
            let gi = row0 + c0 + ri;
            emit(gi, &acc[ri * w.m..(ri + 1) * w.m]);
        }
    }
}

/// Precomputed per-output dequant factors for a row-wise packed weight
/// (`state[j] / 127`, hoisted once per GEMM call — same value, and
/// therefore the same f32 result, as the reference kernel's inline
/// division).
fn row_scales(w: &PackedInt8) -> Option<Vec<f32>> {
    match &w.scale {
        PackedScale::Tensor(_) => None,
        PackedScale::Row(state) => {
            Some(state.iter().map(|s| s / INT8_MAX).collect())
        }
    }
}

/// Dequantize one finished accumulator row into `frow`, replicating the
/// reference kernels' exact f32 expression order (bit-identity contract).
#[inline]
fn epilogue_row(
    w: &PackedInt8,
    swj: Option<&[f32]>,
    x_state_i: f32,
    dots: &[i32],
    frow: &mut [f32],
) {
    match (&w.scale, swj) {
        (PackedScale::Tensor(state), _) => {
            let sw = state / INT8_MAX;
            let scale = (x_state_i / INT8_MAX) * sw;
            for (o, &d) in frow.iter_mut().zip(dots) {
                *o = d as f32 * scale;
            }
        }
        (PackedScale::Row(_), Some(ws)) => {
            let sx = x_state_i / INT8_MAX;
            for ((o, &d), &wj) in frow.iter_mut().zip(dots).zip(ws) {
                *o = d as f32 * sx * wj;
            }
        }
        (PackedScale::Row(_), None) => unreachable!("row scales precomputed"),
    }
}

/// Packed blocked int8 GEMM: `x [b, k]` row-quantized × packed `w [m, k]`
/// → f32 `[b, m]`.  Bit-identical to [`super::gemm_i8_nt_rowtensor`]
/// (tensor-wise scale) / [`super::gemm_i8_nt_rowcol`] (row-wise scale).
pub fn gemm_i8_packed(x: &QuantizedRow, w: &PackedInt8) -> Matrix {
    gemm_i8_packed_with(kernel_isa(), x, w)
}

fn gemm_i8_packed_with(isa: KernelIsa, x: &QuantizedRow, w: &PackedInt8) -> Matrix {
    assert_eq!(x.codes.cols, w.k, "inner dims disagree");
    let (b, m) = (x.codes.rows, w.m);
    let mut out = Matrix::zeros(b, m);
    let ws = row_scales(w);
    let swj = ws.as_deref();
    let workers = num_threads().min(b.max(1));
    if workers <= 1 || b <= 1 {
        let data = &mut out.data[..];
        let mut frow = vec![0.0f32; m];
        dots_rows(isa, x, w, 0, b, |gi, dots| {
            epilogue_row(w, swj, x.state[gi], dots, &mut frow);
            data[gi * m..(gi + 1) * m].copy_from_slice(&frow);
        });
        return out;
    }
    let rows_per = b.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rest = &mut out.data[..];
        let mut row0 = 0usize;
        while !rest.is_empty() {
            let take = (rows_per * m).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            rest = tail;
            let my0 = row0;
            let n = take / m.max(1);
            row0 += n;
            s.spawn(move || {
                let mut frow = vec![0.0f32; m];
                dots_rows(isa, x, w, my0, n, |gi, dots| {
                    epilogue_row(w, swj, x.state[gi], dots, &mut frow);
                    let off = (gi - my0) * m;
                    chunk[off..off + m].copy_from_slice(&frow);
                });
            });
        }
    });
    out
}

/// Packed GEMM with the **fused quantize epilogue**: dequantize each
/// finished row, apply `map` (e.g. gelu) if given, then row-wise quantize
/// it in place — returning the *next* layer's input without ever
/// materializing the full f32 activation matrix.  The output is
/// bit-identical to `rowwise_quant(map(gemm_i8_packed(x, w)))`.
pub fn gemm_i8_packed_fused(
    x: &QuantizedRow,
    w: &PackedInt8,
    map: Option<fn(f32) -> f32>,
) -> QuantizedRow {
    gemm_i8_packed_fused_with(kernel_isa(), x, w, map)
}

fn gemm_i8_packed_fused_with(
    isa: KernelIsa,
    x: &QuantizedRow,
    w: &PackedInt8,
    map: Option<fn(f32) -> f32>,
) -> QuantizedRow {
    assert_eq!(x.codes.cols, w.k, "inner dims disagree");
    let (b, m) = (x.codes.rows, w.m);
    let mut codes = MatrixI8::zeros(b, m);
    let mut state = vec![0.0f32; b];
    let ws = row_scales(w);
    let swj = ws.as_deref();
    let workers = num_threads().min(b.max(1));
    if workers <= 1 || b <= 1 {
        let cdata = &mut codes.data[..];
        let sdata = &mut state[..];
        let mut frow = vec![0.0f32; m];
        dots_rows(isa, x, w, 0, b, |gi, dots| {
            epilogue_row(w, swj, x.state[gi], dots, &mut frow);
            if let Some(f) = map {
                for o in frow.iter_mut() {
                    *o = f(*o);
                }
            }
            sdata[gi] = quantize_row_into(&frow, &mut cdata[gi * m..(gi + 1) * m]);
        });
        return QuantizedRow { codes, state };
    }
    let rows_per = b.div_ceil(workers);
    std::thread::scope(|s| {
        let mut crest = &mut codes.data[..];
        let mut srest = &mut state[..];
        let mut row0 = 0usize;
        while !srest.is_empty() {
            let n = rows_per.min(srest.len());
            let (cchunk, ctail) = crest.split_at_mut(n * m);
            let (schunk, stail) = srest.split_at_mut(n);
            crest = ctail;
            srest = stail;
            let my0 = row0;
            row0 += n;
            s.spawn(move || {
                let mut frow = vec![0.0f32; m];
                dots_rows(isa, x, w, my0, n, |gi, dots| {
                    epilogue_row(w, swj, x.state[gi], dots, &mut frow);
                    if let Some(f) = map {
                        for o in frow.iter_mut() {
                            *o = f(*o);
                        }
                    }
                    let r = gi - my0;
                    schunk[r] =
                        quantize_row_into(&frow, &mut cchunk[r * m..(r + 1) * m]);
                });
            });
        }
    });
    QuantizedRow { codes, state }
}

/// Raw i32 accumulators (row-major `[b, m]`) of the packed kernel — what
/// the equivalence tests compare bit-for-bit against the reference dot
/// loop (single-threaded; a test/debug entry point, not a hot path).
pub fn gemm_i8_packed_i32(x: &QuantizedRow, w: &PackedInt8) -> Vec<i32> {
    gemm_i8_packed_i32_with(kernel_isa(), x, w)
}

fn gemm_i8_packed_i32_with(
    isa: KernelIsa,
    x: &QuantizedRow,
    w: &PackedInt8,
) -> Vec<i32> {
    assert_eq!(x.codes.cols, w.k, "inner dims disagree");
    let (b, m) = (x.codes.rows, w.m);
    let mut out = vec![0i32; b * m];
    dots_rows(isa, x, w, 0, b, |gi, dots| {
        out[gi * m..(gi + 1) * m].copy_from_slice(dots);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::super::i8mm::dot_i8;
    use super::super::{gemm_i8_nt_rowcol, gemm_i8_nt_rowtensor};
    use super::*;
    use crate::nn::gelu;
    use crate::quant::{rowwise_quant, tensorwise_quant};
    use crate::tensor::Rng;

    fn isas() -> Vec<KernelIsa> {
        let mut v = vec![KernelIsa::Scalar];
        #[cfg(target_arch = "x86_64")]
        {
            v.push(KernelIsa::Sse2);
            if is_x86_feature_detected!("avx2") {
                v.push(KernelIsa::Avx2);
            }
        }
        v
    }

    /// Shape matrix for the equivalence tests: non-multiples of MR/KP/RB
    /// on every axis, degenerate b=1 / m=1, and tile-aligned controls.
    fn shapes() -> Vec<(usize, usize, usize)> {
        vec![
            (1, 1, 1),
            (1, 40, 24),   // b = 1
            (24, 40, 1),   // m = 1
            (3, 5, 7),     // everything tiny and odd
            (17, 33, 29),  // nothing tile-aligned
            (16, 32, 24),  // fully tile-aligned control
            (65, 129, 63), // crosses RB / KP / MR boundaries by one
            (9, 100, 37),
        ]
    }

    #[test]
    fn packing_is_lossless_relayout() {
        let mut rng = Rng::seed(21);
        for (mm, kk) in [(24, 40), (7, 13), (1, 1), (33, 17)] {
            let w = Matrix::randn(mm, kk, 1.0, &mut rng);
            let q = tensorwise_quant(&w);
            let packed = PackedInt8::pack_tensorwise(&q);
            let fused = PackedInt8::quantize_tensorwise(&w);
            assert_eq!(packed.data, fused.data, "{mm}x{kk}: fused != pack(quant)");
            // spot-decode: every logical code must be recoverable
            for row in 0..mm {
                let (p, r) = (row / MR, row % MR);
                for col in 0..kk {
                    let (kb, c) = (col / KP, col % KP);
                    let idx = ((p * packed.kblocks + kb) * MR + r) * KP + c;
                    assert_eq!(
                        packed.data[idx],
                        q.codes.row(row)[col],
                        "{mm}x{kk} at ({row},{col})"
                    );
                }
            }
        }
    }

    #[test]
    fn quantize_transpose_pack_matches_pack_of_transposed() {
        let mut rng = Rng::seed(22);
        for (mm, kk) in [(24, 40), (7, 13), (33, 17), (1, 9)] {
            let w = Matrix::randn(mm, kk, 1.0, &mut rng);
            let a = PackedInt8::quantize_tensorwise_transpose(&w);
            let b = PackedInt8::quantize_tensorwise(&w.transpose());
            assert_eq!(a.data, b.data, "{mm}x{kk}");
            assert_eq!(a.m, kk);
            assert_eq!(a.k, mm);
            match (&a.scale, &b.scale) {
                (PackedScale::Tensor(x), PackedScale::Tensor(y)) => {
                    assert_eq!(x, y)
                }
                _ => panic!("wrong scale kind"),
            }
        }
    }

    /// The tentpole invariant: every ISA's blocked kernel produces the
    /// exact i32 accumulators of the reference dot loop, on shapes that
    /// are deliberately hostile to the tile grid.
    #[test]
    fn blocked_i32_bit_identical_to_reference_all_isas() {
        let mut rng = Rng::seed(23);
        for (b, k, m) in shapes() {
            let x = Matrix::randn(b, k, 1.0, &mut rng);
            let w = Matrix::randn(m, k, 0.5, &mut rng);
            let xq = rowwise_quant(&x);
            let wq = tensorwise_quant(&w);
            let packed = PackedInt8::pack_tensorwise(&wq);
            let mut reference = vec![0i32; b * m];
            for i in 0..b {
                for j in 0..m {
                    reference[i * m + j] =
                        dot_i8(xq.codes.row(i), wq.codes.row(j));
                }
            }
            for isa in isas() {
                let got = gemm_i8_packed_i32_with(isa, &xq, &packed);
                assert_eq!(
                    got, reference,
                    "i32 accumulators differ: {b}x{k}x{m} on {isa:?}"
                );
            }
        }
    }

    /// All-saturated ±127 codes at the largest magnitudes the kernel can
    /// see — the worst case for any madd overflow mistake.
    #[test]
    fn saturated_codes_accumulate_exactly() {
        let k = 129; // odd, crosses KP
        let (b, m) = (5, 11);
        let mut x = Matrix::zeros(b, k);
        let mut w = Matrix::zeros(m, k);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = if i % 2 == 0 { 1.0 } else { -1.0 };
        }
        for (i, v) in w.data.iter_mut().enumerate() {
            *v = if i % 3 == 0 { -1.0 } else { 1.0 };
        }
        let xq = rowwise_quant(&x);
        let wq = tensorwise_quant(&w);
        assert!(xq.codes.data.iter().all(|&c| c == 127 || c == -127));
        assert!(wq.codes.data.iter().all(|&c| c == 127 || c == -127));
        let packed = PackedInt8::pack_tensorwise(&wq);
        let mut reference = vec![0i32; b * m];
        for i in 0..b {
            for j in 0..m {
                reference[i * m + j] = dot_i8(xq.codes.row(i), wq.codes.row(j));
            }
        }
        for isa in isas() {
            assert_eq!(
                gemm_i8_packed_i32_with(isa, &xq, &packed),
                reference,
                "{isa:?}"
            );
        }
    }

    /// f32 epilogue identity vs the reference GEMMs, both scale kinds.
    #[test]
    fn packed_f32_output_bit_identical_to_reference() {
        let mut rng = Rng::seed(24);
        for (b, k, m) in shapes() {
            let x = Matrix::randn(b, k, 1.0, &mut rng);
            let w = Matrix::randn(m, k, 0.5, &mut rng);
            let xq = rowwise_quant(&x);
            // tensor-wise scale
            let wt = tensorwise_quant(&w);
            let want = gemm_i8_nt_rowtensor(&xq, &wt);
            let packed = PackedInt8::pack_tensorwise(&wt);
            for isa in isas() {
                let got = gemm_i8_packed_with(isa, &xq, &packed);
                assert_eq!(
                    got.max_abs_diff(&want),
                    0.0,
                    "rowtensor {b}x{k}x{m} on {isa:?}"
                );
            }
            // row-wise scale
            let wr = rowwise_quant(&w);
            let want = gemm_i8_nt_rowcol(&xq, &wr);
            let packed = PackedInt8::pack_rowwise(&wr);
            for isa in isas() {
                let got = gemm_i8_packed_with(isa, &xq, &packed);
                assert_eq!(
                    got.max_abs_diff(&want),
                    0.0,
                    "rowcol {b}x{k}x{m} on {isa:?}"
                );
            }
        }
    }

    /// Fused epilogue ≡ unfused GEMM → map → rowwise_quant, bit-for-bit.
    #[test]
    fn fused_quant_epilogue_matches_unfused_pipeline() {
        let mut rng = Rng::seed(25);
        for (b, k, m) in shapes() {
            let x = Matrix::randn(b, k, 1.0, &mut rng);
            let w = Matrix::randn(m, k, 0.5, &mut rng);
            let xq = rowwise_quant(&x);
            let packed = PackedInt8::quantize_tensorwise(&w);
            for map in [None, Some(gelu as fn(f32) -> f32)] {
                let mut y = gemm_i8_packed(&xq, &packed);
                if let Some(f) = map {
                    for v in y.data.iter_mut() {
                        *v = f(*v);
                    }
                }
                let want = rowwise_quant(&y);
                for isa in isas() {
                    let got = gemm_i8_packed_fused_with(isa, &xq, &packed, map);
                    assert_eq!(got.codes.data, want.codes.data,
                        "fused codes differ: {b}x{k}x{m} {isa:?} map={}",
                        map.is_some());
                    assert_eq!(got.state, want.state,
                        "fused state differs: {b}x{k}x{m} {isa:?}");
                }
            }
        }
    }

    /// Threaded and single-threaded paths agree (row split is exact).
    #[test]
    fn threaded_split_matches_serial() {
        let _lock = crate::util::threads::THREADS_ENV_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut rng = Rng::seed(26);
        let x = Matrix::randn(37, 50, 1.0, &mut rng);
        let w = Matrix::randn(23, 50, 0.5, &mut rng);
        let xq = rowwise_quant(&x);
        let packed = PackedInt8::quantize_tensorwise(&w);
        let parallel = gemm_i8_packed(&xq, &packed);
        let fused_par = gemm_i8_packed_fused(&xq, &packed, None);
        std::env::set_var("SWITCHBACK_THREADS", "1");
        let serial = gemm_i8_packed(&xq, &packed);
        let fused_ser = gemm_i8_packed_fused(&xq, &packed, None);
        std::env::remove_var("SWITCHBACK_THREADS");
        assert_eq!(parallel.max_abs_diff(&serial), 0.0);
        assert_eq!(fused_par.codes.data, fused_ser.codes.data);
        assert_eq!(fused_par.state, fused_ser.state);
    }
}
