//! Native GEMM substrate — the *measured-speed* stand-in for the paper's
//! A100 int8 tensor-core kernels (DESIGN.md §Substitutions).
//!
//! The paper's Fig 3/4/13 measure Triton int8 kernels against fp16 cuBLAS;
//! we measure a packed cache-blocked i8×i8→i32 GEMM ([`pack`]) against an
//! equally-optimized f32 GEMM.  The *shape* of the result carries over:
//! 8-bit operands quarter (vs f32) the memory traffic and widen the SIMD
//! lanes, while quantize ops are O(n²) against the matmul's O(n³), so
//! SwitchBack's advantage grows with `dim` and `batch×seq`.
//!
//! Layout conventions (matching the paper's observation that int8 hardware
//! only implements `A Bᵀ`): all kernels are "NT" — both operands row-major,
//! contracting over their *columns*, so every dot product runs over two
//! contiguous rows and vectorizes.
//!
//! ## The one dispatch point: [`MatmulPlan`]
//!
//! Every linear layer's numerics are a *plan* — which form the weight is
//! quantized to, and which of the three matmuls (fwd / dgrad / wgrad) run
//! in int8 — held as plain data.  `MatmulPlan` replaces the old
//! `StandardLinearOps` / `SwitchBackOps` / `LlmInt8Ops` structs and the
//! per-kind match arms that were copy-pasted across `Linear::forward`,
//! `Linear::forward_infer` and `PreparedLinear::forward`; callers pick a
//! plan once (`LinearKind::plan()`) and every path funnels through it.
//! All int8 matmuls run on the packed blocked kernel; the flat-layout
//! kernels in [`i8mm`] remain as the reference oracles it is tested
//! bit-for-bit against.

mod f32mm;
mod i8mm;
mod pack;

pub use f32mm::{gemm_f32_nn, gemm_f32_nt};
pub use i8mm::{gemm_i8_nt_rowcol, gemm_i8_nt_rowtensor};
pub use pack::{
    gemm_i8_packed, gemm_i8_packed_fused, gemm_i8_packed_i32, kernel_isa,
    KernelIsa, PackedInt8, PackedScale, KP, MR,
};

use crate::quant::{QuantScheme, QuantScratch, QuantizedRow};
use crate::tensor::Matrix;
use std::cell::RefCell;

thread_local! {
    /// Per-thread activation-quantization scratch: the serve/infer hot
    /// path row-quantizes into these reused buffers, allocating nothing
    /// per call once warm.
    static ACT_SCRATCH: RefCell<QuantScratch> = RefCell::new(QuantScratch::new());
}

/// Row-quantize `x` into the thread-local scratch and run `f` on it.
fn with_quantized<R>(x: &Matrix, f: impl FnOnce(&QuantizedRow) -> R) -> R {
    ACT_SCRATCH.with(|s| {
        let mut s = s.borrow_mut();
        f(s.rowwise(x))
    })
}

/// The form a plan's weight operand takes in its forward matmul.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightForm {
    /// full-precision f32 (Standard baseline, Algorithm 5)
    F32,
    /// int8 codes + one scalar state (SwitchBack, eq. 2)
    TensorWise,
    /// int8 codes + per-output-row state (LLM.int8(), eq. 1)
    RowWise,
}

impl WeightForm {
    /// The quantization scheme this form applies to the weight, if any.
    pub fn scheme(&self) -> Option<QuantScheme> {
        match self {
            Self::F32 => None,
            Self::TensorWise => Some(QuantScheme::TensorWise),
            Self::RowWise => Some(QuantScheme::RowWise),
        }
    }
}

/// A linear layer's numerics as data: weight form + which matmuls run in
/// int8 + what the backward cache holds.  One `match`-free dispatch point
/// for training forward/backward, inference, and prepare-time packing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MatmulPlan {
    /// forward weight form (also the prepared/served form)
    pub weight: WeightForm,
    /// dgrad `dX = G W` runs int8 (row-quantized G × quantized Wᵀ)
    pub int8_dgrad: bool,
    /// wgrad `dW = Gᵀ X` runs int8 (the noisy one — Appendix C)
    pub int8_wgrad: bool,
    /// backward cache keeps int8 X codes instead of f32 X (Algorithm 3)
    pub cache_codes: bool,
}

impl MatmulPlan {
    /// Algorithm 5: all three matmuls full precision.
    pub const fn standard() -> Self {
        Self {
            weight: WeightForm::F32,
            int8_dgrad: false,
            int8_wgrad: false,
            cache_codes: false,
        }
    }

    /// Algorithm 1 (`memory_efficient: false`) or Algorithm 3 (`true`):
    /// int8 fwd + dgrad, exact f32 wgrad.
    pub const fn switchback(memory_efficient: bool) -> Self {
        Self {
            weight: WeightForm::TensorWise,
            int8_dgrad: true,
            int8_wgrad: false,
            cache_codes: memory_efficient,
        }
    }

    /// LLM.int8()-style: all three matmuls int8 (Fig 13 comparator).
    pub const fn llm_int8() -> Self {
        Self {
            weight: WeightForm::RowWise,
            int8_dgrad: true,
            int8_wgrad: true,
            cache_codes: false,
        }
    }

    /// Whether the forward path row-quantizes its activations (callers
    /// that already hold codes can take the `forward_quantized` door).
    pub fn quantizes_activations(&self) -> bool {
        !matches!(self.weight, WeightForm::F32)
    }

    /// Training/inference forward: `x [b, n]`, `w [m, n]` → `[b, m]`.
    pub fn forward(&self, x: &Matrix, w: &Matrix) -> Matrix {
        match self.weight.scheme() {
            None => gemm_f32_nt(x, w),
            Some(s) => {
                let packed = PackedInt8::quantize(s, w);
                with_quantized(x, |xq| gemm_i8_packed(xq, &packed))
            }
        }
    }

    /// Forward from already-quantized activations (shared codes — e.g. one
    /// row-quantize feeding Q, K and V).  Int8 plans only.
    pub fn forward_quantized(&self, xq: &QuantizedRow, w: &Matrix) -> Matrix {
        let s = self
            .weight
            .scheme()
            .expect("f32 plan has no quantized forward");
        gemm_i8_packed(xq, &PackedInt8::quantize(s, w))
    }

    /// Forward with the fused quantize epilogue: dequantize, apply `map`
    /// (e.g. gelu), and row-quantize each output row in one pass — the
    /// next int8 layer's input without an f32 round-trip through memory.
    pub fn forward_fused_quant(
        &self,
        xq: &QuantizedRow,
        w: &Matrix,
        map: Option<fn(f32) -> f32>,
    ) -> QuantizedRow {
        let s = self
            .weight
            .scheme()
            .expect("f32 plan has no fused-quant forward");
        gemm_i8_packed_fused(xq, &PackedInt8::quantize(s, w), map)
    }

    /// dgrad: `g [b, m]`, `w [m, n]` → `dX [b, n]`.
    pub fn dgrad(&self, g: &Matrix, w: &Matrix) -> Matrix {
        if !self.int8_dgrad {
            return gemm_f32_nn(g, w);
        }
        let packed = match self.weight {
            // fused quantize+transpose (§2.2.1): Wᵀ codes in one pass
            WeightForm::TensorWise => {
                PackedInt8::quantize(QuantScheme::TensorWiseTranspose, w)
            }
            WeightForm::RowWise => PackedInt8::quantize_rowwise(&w.transpose()),
            WeightForm::F32 => unreachable!("int8 dgrad requires int8 weight"),
        };
        with_quantized(g, |gq| gemm_i8_packed(gq, &packed))
    }

    /// wgrad: `g [b, m]`, `x [b, n]` → `dW [m, n]` (inner dim = b =
    /// batch×seq — which is why the int8 variant is the noisy one).
    pub fn wgrad(&self, g: &Matrix, x: &Matrix) -> Matrix {
        let gt = g.transpose();
        if !self.int8_wgrad {
            return gemm_f32_nn(&gt, x);
        }
        let packed = PackedInt8::quantize_rowwise(&x.transpose());
        with_quantized(&gt, |gq| gemm_i8_packed(gq, &packed))
    }

    /// Pack the weight once (load/prepare time) into the form this plan's
    /// forward consumes — int8 plans keep only packed codes + state.
    pub fn prepare(&self, w: &Matrix) -> PreparedWeight {
        match self.weight.scheme() {
            None => PreparedWeight::Full(w.clone()),
            Some(s) => PreparedWeight::Packed(PackedInt8::quantize(s, w)),
        }
    }
}

/// A weight stored in the form its forward matmul consumes, built once at
/// prepare time: f32 for standard plans, packed tile-major int8 codes for
/// quantized plans (≈4× less resident memory, zero per-call weight work).
#[derive(Debug, Clone)]
pub enum PreparedWeight {
    /// f32 weight (Standard)
    Full(Matrix),
    /// packed int8 codes + state (SwitchBack / SwitchBackM / LLM.int8())
    Packed(PackedInt8),
}

impl PreparedWeight {
    /// `x [b, in] → [b, out]`, activations quantized into the per-thread
    /// scratch (no per-call allocation of codes).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        match self {
            Self::Full(w) => gemm_f32_nt(x, w),
            Self::Packed(p) => with_quantized(x, |xq| gemm_i8_packed(xq, p)),
        }
    }

    /// Forward from shared, already-quantized activations.
    pub fn forward_quant(&self, xq: &QuantizedRow) -> Matrix {
        match self {
            Self::Full(_) => panic!("f32 weight has no quantized forward"),
            Self::Packed(p) => gemm_i8_packed(xq, p),
        }
    }

    /// Forward with the fused map+quantize epilogue (see
    /// [`MatmulPlan::forward_fused_quant`]).
    pub fn forward_fused_quant(
        &self,
        xq: &QuantizedRow,
        map: Option<fn(f32) -> f32>,
    ) -> QuantizedRow {
        match self {
            Self::Full(_) => panic!("f32 weight has no fused-quant forward"),
            Self::Packed(p) => gemm_i8_packed_fused(xq, p, map),
        }
    }

    /// Resident weight bytes (codes + state, or f32 data).
    pub fn bytes(&self) -> usize {
        match self {
            Self::Full(w) => w.data.len() * 4,
            Self::Packed(p) => p.bytes(),
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, Self::Packed(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{rowwise_quant, tensorwise_quant};
    use crate::tensor::Rng;

    fn rel_err(a: &Matrix, b: &Matrix) -> f32 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (x, y) in a.data.iter().zip(&b.data) {
            num += ((x - y) as f64).powi(2);
            den += (*y as f64).powi(2);
        }
        (num / den.max(1e-30)).sqrt() as f32
    }

    #[test]
    fn switchback_forward_close_to_f32() {
        let mut rng = Rng::seed(11);
        let x = Matrix::randn(64, 96, 1.0, &mut rng);
        let w = Matrix::randn(48, 96, 0.1, &mut rng);
        let yq = MatmulPlan::switchback(false).forward(&x, &w);
        let y = MatmulPlan::standard().forward(&x, &w);
        let e = rel_err(&yq, &y);
        assert!(e < 0.03, "quantization rel err too big: {e}");
    }

    #[test]
    fn dgrad_matches_f32_within_quant_noise() {
        let mut rng = Rng::seed(12);
        let g = Matrix::randn(64, 48, 1.0, &mut rng);
        let w = Matrix::randn(48, 96, 0.1, &mut rng);
        let dq = MatmulPlan::switchback(false).dgrad(&g, &w);
        let d = MatmulPlan::standard().dgrad(&g, &w);
        assert!(rel_err(&dq, &d) < 0.03);
    }

    #[test]
    fn llmint8_wgrad_noisier_than_switchback_wgrad() {
        // The paper's core claim (Appendix C): the int8 wgrad is the noisy
        // one because its inner dimension is batch×seq.
        let mut rng = Rng::seed(13);
        let b = 2048; // large inner dim
        let g = Matrix::randn(b, 32, 1.0, &mut rng);
        let x = Matrix::randn(b, 32, 1.0, &mut rng);
        let exact = MatmulPlan::standard().wgrad(&g, &x);
        let sb = MatmulPlan::switchback(false).wgrad(&g, &x); // f32: exact
        let llm = MatmulPlan::llm_int8().wgrad(&g, &x); // int8: noisy
        assert_eq!(rel_err(&sb, &exact), 0.0);
        let e = rel_err(&llm, &exact);
        assert!(e > 0.01, "int8 wgrad should be visibly noisy, got {e}");
    }

    /// The plan's packed forward reproduces the reference flat kernel
    /// bit-for-bit — the redesign changes the API, not one ulp of output.
    #[test]
    fn plan_forward_bit_identical_to_reference_kernels() {
        let mut rng = Rng::seed(14);
        let x = Matrix::randn(33, 70, 1.0, &mut rng);
        let w = Matrix::randn(27, 70, 0.1, &mut rng);
        let xq = rowwise_quant(&x);
        // switchback: reference = flat rowtensor kernel
        let want = gemm_i8_nt_rowtensor(&xq, &tensorwise_quant(&w));
        let got = MatmulPlan::switchback(false).forward(&x, &w);
        assert_eq!(got.max_abs_diff(&want), 0.0);
        // llm.int8: reference = flat rowcol kernel
        let want = gemm_i8_nt_rowcol(&xq, &rowwise_quant(&w));
        let got = MatmulPlan::llm_int8().forward(&x, &w);
        assert_eq!(got.max_abs_diff(&want), 0.0);
        // prepared path is the same numerics
        let prep = MatmulPlan::switchback(false).prepare(&w);
        assert!(prep.is_quantized());
        assert_eq!(prep.forward(&x).max_abs_diff(
            &MatmulPlan::switchback(false).forward(&x, &w)), 0.0);
    }

    /// dgrad through the fused quantize+transpose equals dgrad against an
    /// explicitly transposed, tensor-quantized weight (the §2.2.1 fusion
    /// is a layout optimization, not a numeric change).
    #[test]
    fn dgrad_fused_transpose_matches_explicit_transpose() {
        let mut rng = Rng::seed(15);
        let g = Matrix::randn(21, 17, 1.0, &mut rng);
        let w = Matrix::randn(17, 39, 0.1, &mut rng);
        let got = MatmulPlan::switchback(false).dgrad(&g, &w);
        let gq = rowwise_quant(&g);
        let wt = tensorwise_quant(&w.transpose());
        let want = gemm_i8_nt_rowtensor(&gq, &wt);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }
}
