//! Native GEMM substrate — the *measured-speed* stand-in for the paper's
//! A100 int8 tensor-core kernels (DESIGN.md §Substitutions).
//!
//! The paper's Fig 3/4/13 measure Triton int8 kernels against fp16 cuBLAS;
//! we measure a rayon-parallel, cache-blocked i8×i8→i32 GEMM against an
//! equally-optimized f32 GEMM.  The *shape* of the result carries over:
//! 8-bit operands halve (vs f32: quarter) the memory traffic and widen the
//! SIMD lanes, while quantize ops are O(n²) against the matmul's O(n³), so
//! SwitchBack's advantage grows with `dim` and `batch×seq`.
//!
//! Layout conventions (matching the paper's observation that int8 hardware
//! only implements `A Bᵀ`): all kernels are "NT" — both operands row-major,
//! contracting over their *columns*, so every dot product runs over two
//! contiguous rows and vectorizes.

mod f32mm;
mod i8mm;

pub use f32mm::{gemm_f32_nn, gemm_f32_nt};
pub use i8mm::{gemm_i8_nt_rowcol, gemm_i8_nt_rowtensor};

use crate::quant::{
    rowwise_quant, tensorwise_quant, tensorwise_quant_transpose,
};
use crate::tensor::Matrix;

/// The three matmuls of a standard linear layer, full precision
/// (Algorithm 5 — the `torch.autograd` baseline):
/// fwd `Y = X Wᵀ`, dgrad `dX = G W`, wgrad `dW = Gᵀ X`.
pub struct StandardLinearOps;

impl StandardLinearOps {
    /// `x [b, n]`, `w [m, n]` → `[b, m]`
    pub fn forward(x: &Matrix, w: &Matrix) -> Matrix {
        gemm_f32_nt(x, w)
    }

    /// `g [b, m]`, `w [m, n]` → `[b, n]`
    pub fn dgrad(g: &Matrix, w: &Matrix) -> Matrix {
        gemm_f32_nn(g, w)
    }

    /// `g [b, m]`, `x [b, n]` → `[m, n]` (inner dim = b = batch×seq)
    pub fn wgrad(g: &Matrix, x: &Matrix) -> Matrix {
        let gt = g.transpose();
        gemm_f32_nn(&gt, x)
    }
}

/// The SwitchBack linear layer ops (Algorithm 1) on the native substrate:
/// int8 fwd + dgrad, f32 wgrad.
pub struct SwitchBackOps;

impl SwitchBackOps {
    pub fn forward(x: &Matrix, w: &Matrix) -> Matrix {
        let xq = rowwise_quant(x);
        let wq = tensorwise_quant(w);
        gemm_i8_nt_rowtensor(&xq, &wq)
    }

    pub fn dgrad(g: &Matrix, w: &Matrix) -> Matrix {
        let gq = rowwise_quant(g);
        // fused quantize+transpose: Wᵀ codes in one pass (§2.2.1)
        let wtq = tensorwise_quant_transpose(w);
        gemm_i8_nt_rowtensor(&gq, &wtq)
    }

    pub fn wgrad(g: &Matrix, x: &Matrix) -> Matrix {
        StandardLinearOps::wgrad(g, x)
    }
}

/// LLM.int8()-style ops: all three matmuls in int8 (Fig 13 comparator).
pub struct LlmInt8Ops;

impl LlmInt8Ops {
    pub fn forward(x: &Matrix, w: &Matrix) -> Matrix {
        let xq = rowwise_quant(x);
        let wq = rowwise_quant(w);
        gemm_i8_nt_rowcol(&xq, &wq)
    }

    pub fn dgrad(g: &Matrix, w: &Matrix) -> Matrix {
        let gq = rowwise_quant(g);
        let wt = w.transpose();
        let wtq = rowwise_quant(&wt);
        gemm_i8_nt_rowcol(&gq, &wtq)
    }

    pub fn wgrad(g: &Matrix, x: &Matrix) -> Matrix {
        let gt = g.transpose();
        let gq = rowwise_quant(&gt);
        let xt = x.transpose();
        let xq = rowwise_quant(&xt);
        gemm_i8_nt_rowcol(&gq, &xq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn rel_err(a: &Matrix, b: &Matrix) -> f32 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (x, y) in a.data.iter().zip(&b.data) {
            num += ((x - y) as f64).powi(2);
            den += (*y as f64).powi(2);
        }
        (num / den.max(1e-30)).sqrt() as f32
    }

    #[test]
    fn switchback_forward_close_to_f32() {
        let mut rng = Rng::seed(11);
        let x = Matrix::randn(64, 96, 1.0, &mut rng);
        let w = Matrix::randn(48, 96, 0.1, &mut rng);
        let yq = SwitchBackOps::forward(&x, &w);
        let y = StandardLinearOps::forward(&x, &w);
        let e = rel_err(&yq, &y);
        assert!(e < 0.03, "quantization rel err too big: {e}");
    }

    #[test]
    fn dgrad_matches_f32_within_quant_noise() {
        let mut rng = Rng::seed(12);
        let g = Matrix::randn(64, 48, 1.0, &mut rng);
        let w = Matrix::randn(48, 96, 0.1, &mut rng);
        let dq = SwitchBackOps::dgrad(&g, &w);
        let d = StandardLinearOps::dgrad(&g, &w);
        assert!(rel_err(&dq, &d) < 0.03);
    }

    #[test]
    fn llmint8_wgrad_noisier_than_switchback_wgrad() {
        // The paper's core claim (Appendix C): the int8 wgrad is the noisy
        // one because its inner dimension is batch×seq.
        let mut rng = Rng::seed(13);
        let b = 2048; // large inner dim
        let g = Matrix::randn(b, 32, 1.0, &mut rng);
        let x = Matrix::randn(b, 32, 1.0, &mut rng);
        let exact = StandardLinearOps::wgrad(&g, &x);
        let sb = SwitchBackOps::wgrad(&g, &x); // f32: exact
        let llm = LlmInt8Ops::wgrad(&g, &x); // int8: noisy
        assert_eq!(rel_err(&sb, &exact), 0.0);
        let e = rel_err(&llm, &exact);
        assert!(e > 0.01, "int8 wgrad should be visibly noisy, got {e}");
    }
}
