//! f32 GEMM kernels (the "16-bit baseline" stand-in).
//!
//! Two variants for the two memory layouts a linear layer needs:
//! * `gemm_f32_nt` — `A [m,k] · Bᵀ` with `B [n,k]`: dot products over two
//!   contiguous rows (forward + wgrad-after-transpose path).
//! * `gemm_f32_nn` — `A [m,k] · B [k,n]`: k-outer axpy form, streaming
//!   through contiguous rows of B (dgrad path).
//!
//! Both are rayon-parallel over output row blocks and cache-blocked over k.

use crate::tensor::Matrix;
use crate::util::threads::par_chunks_mut;

/// Contraction block: keeps an `KB`-long stripe of both operands in L1/L2.
const KB: usize = 256;

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    // 4-way unrolled dot; LLVM vectorizes each lane independently which
    // breaks the fp-add dependency chain (≈3–4× vs the naive loop).
    let n = a.len().min(b.len());
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc0 += a[j] * b[j];
        acc1 += a[j + 1] * b[j + 1];
        acc2 += a[j + 2] * b[j + 2];
        acc3 += a[j + 3] * b[j + 3];
    }
    for j in chunks * 4..n {
        acc0 += a[j] * b[j];
    }
    acc0 + acc1 + acc2 + acc3
}

/// `a [m, k] @ b [n, k]ᵀ → [m, n]`.
pub fn gemm_f32_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "inner dims disagree");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut out = Matrix::zeros(m, n);
    par_chunks_mut(&mut out.data, n, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + r;
            let arow = &a.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                orow[j] = dot(arow, brow);
            }
        }
    });
    out
}

/// `a [m, k] @ b [k, n] → [m, n]` (k-blocked axpy form).
pub fn gemm_f32_nn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "inner dims disagree");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = Matrix::zeros(m, n);
    par_chunks_mut(&mut out.data, n, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + r;
            let arow = &a.data[i * k..(i + 1) * k];
            for p0 in (0..k).step_by(KB) {
                let p1 = (p0 + KB).min(k);
                for p in p0..p1 {
                    let av = arow[p];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b.data[p * n..(p + 1) * n];
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn nt_matches_naive() {
        let mut rng = Rng::seed(21);
        let a = Matrix::randn(17, 33, 1.0, &mut rng);
        let b = Matrix::randn(9, 33, 1.0, &mut rng);
        let fast = gemm_f32_nt(&a, &b);
        let slow = a.matmul_naive(&b.transpose());
        assert!(fast.max_abs_diff(&slow) < 1e-4);
    }

    #[test]
    fn nn_matches_naive() {
        let mut rng = Rng::seed(22);
        let a = Matrix::randn(13, 600, 1.0, &mut rng);
        let b = Matrix::randn(600, 11, 1.0, &mut rng);
        let fast = gemm_f32_nn(&a, &b);
        let slow = a.matmul_naive(&b);
        assert!(fast.max_abs_diff(&slow) < 1e-3);
    }

    #[test]
    fn empty_edge_cases() {
        let a = Matrix::zeros(0, 5);
        let b = Matrix::zeros(3, 5);
        let out = gemm_f32_nt(&a, &b);
        assert_eq!((out.rows, out.cols), (0, 3));
    }
}
