//! int8 GEMM with int32 accumulation + fused dequantize epilogue.
//!
//! The native mirror of the L1 Pallas kernel
//! (`kernels/switchback.py::int8_matmul_dequant`): exact i32 accumulation,
//! then the `state/127` rescale applied once per output element.  On this
//! CPU the win comes from 4×-narrower operands (memory bandwidth) and
//! 16-lane widening integer SIMD; on the paper's A100 it came from int8
//! tensor cores — either way int8 beats the float baseline and the margin
//! grows with the matmul size (Fig 3).

use crate::quant::{QuantizedRow, QuantizedTensor, INT8_MAX};
use crate::tensor::Matrix;
use crate::util::threads::par_chunks_mut;

/// Reference int8 dot product — the oracle the packed kernel
/// ([`super::pack`]) is tested bit-for-bit against.
///
/// Mismatched inner dims are a caller bug, enforced at this kernel
/// boundary: the old `min(len)` truncation silently produced a wrong
/// (partial) dot instead of failing.
#[inline]
pub(crate) fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(
        a.len(),
        b.len(),
        "dot_i8 inner dims disagree ({} vs {})",
        a.len(),
        b.len()
    );
    // i8×i8 products fit in i16 (≤127² = 16129); accumulating i16 products
    // into i32 lanes is the pmaddwd pattern LLVM's autovectorizer
    // recognizes (≈3× over naive i32 widening on SSE2/AVX2 — §Perf log).
    let n = a.len();
    let mut acc = [0i32; 8];
    let chunks = n / 8;
    for i in 0..chunks {
        let j = i * 8;
        for l in 0..8 {
            acc[l] += (a[j + l] as i16 as i32) * (b[j + l] as i16 as i32);
        }
    }
    let mut total: i32 = acc.iter().sum();
    for j in chunks * 8..n {
        total += a[j] as i32 * b[j] as i32;
    }
    total
}

/// `x` row-wise quantized `[b, k]`, `w` tensor-wise quantized `[m, k]`
/// → f32 `[b, m]` (paper eq. 3: SwitchBack fwd/dgrad).
pub fn gemm_i8_nt_rowtensor(x: &QuantizedRow, w: &QuantizedTensor) -> Matrix {
    assert_eq!(x.codes.cols, w.codes.cols, "inner dims disagree");
    let (b, k, m) = (x.codes.rows, x.codes.cols, w.codes.rows);
    let sw = w.state / INT8_MAX;
    let mut out = Matrix::zeros(b, m);
    par_chunks_mut(&mut out.data, m, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(m).enumerate() {
            let i = row0 + r;
            let xrow = &x.codes.data[i * k..(i + 1) * k];
            let scale = (x.state[i] / INT8_MAX) * sw;
            for j in 0..m {
                let wrow = &w.codes.data[j * k..(j + 1) * k];
                orow[j] = dot_i8(xrow, wrow) as f32 * scale;
            }
        }
    });
    out
}

/// `x` row-wise `[b, k]`, `w` row-wise-per-output `[m, k]` (both vectors of
/// states) → f32 `[b, m]` (paper eq. 4: SwitchBackQ / LLM.int8()).
pub fn gemm_i8_nt_rowcol(x: &QuantizedRow, w: &QuantizedRow) -> Matrix {
    assert_eq!(x.codes.cols, w.codes.cols, "inner dims disagree");
    let (b, k, m) = (x.codes.rows, x.codes.cols, w.codes.rows);
    let mut out = Matrix::zeros(b, m);
    par_chunks_mut(&mut out.data, m, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(m).enumerate() {
            let i = row0 + r;
            let xrow = &x.codes.data[i * k..(i + 1) * k];
            let sx = x.state[i] / INT8_MAX;
            for j in 0..m {
                let wrow = &w.codes.data[j * k..(j + 1) * k];
                orow[j] = dot_i8(xrow, wrow) as f32 * sx * (w.state[j] / INT8_MAX);
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{rowwise_quant, tensorwise_quant};
    use crate::tensor::Rng;

    /// Exhaustive small case: i32 accumulation must be exact.
    #[test]
    fn exact_integer_accumulation() {
        let x = Matrix::from_vec(1, 3, vec![127.0, -127.0, 64.0]);
        let w = Matrix::from_vec(1, 3, vec![127.0, 127.0, 127.0]);
        let xq = rowwise_quant(&x);
        let wq = tensorwise_quant(&w);
        let out = gemm_i8_nt_rowtensor(&xq, &wq);
        // codes: x = [127,-127,64], w = [127,127,127]
        // acc = 127*127 - 127*127 + 64*127 = 8128
        // scale = (127/127)*(127/127) = 1
        assert_eq!(out.data[0], 8128.0);
    }

    #[test]
    fn matches_dequantized_float_matmul() {
        let mut rng = Rng::seed(31);
        let x = Matrix::randn(20, 50, 1.0, &mut rng);
        let w = Matrix::randn(15, 50, 1.0, &mut rng);
        let xq = rowwise_quant(&x);
        let wq = tensorwise_quant(&w);
        let fast = gemm_i8_nt_rowtensor(&xq, &wq);
        // Oracle: dequantize codes to f32 then run the float GEMM.
        let xd = crate::quant::dequant_rowwise(&xq);
        let mut wd = Matrix::zeros(15, 50);
        for (o, &c) in wd.data.iter_mut().zip(&wq.codes.data) {
            *o = c as f32 * wq.state / 127.0;
        }
        let slow = super::super::gemm_f32_nt(&xd, &wd);
        assert!(fast.max_abs_diff(&slow) < 1e-3);
    }

    /// The silent-truncation bug is gone: mismatched inner dims now trip
    /// the kernel-boundary invariant (debug builds) instead of returning
    /// a partial dot.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "dot_i8 inner dims disagree")]
    fn mismatched_inner_dims_panic_in_debug() {
        let a = [1i8, 2, 3, 4];
        let b = [1i8, 2, 3];
        let _ = dot_i8(&a, &b);
    }

    #[test]
    fn rowcol_matches_dequantized() {
        let mut rng = Rng::seed(32);
        let x = Matrix::randn(8, 40, 1.0, &mut rng);
        let w = Matrix::randn(6, 40, 1.0, &mut rng);
        let xq = rowwise_quant(&x);
        let wq = rowwise_quant(&w);
        let fast = gemm_i8_nt_rowcol(&xq, &wq);
        let xd = crate::quant::dequant_rowwise(&xq);
        let wd = crate::quant::dequant_rowwise(&wq);
        let slow = super::super::gemm_f32_nt(&xd, &wd);
        assert!(fast.max_abs_diff(&slow) < 1e-3);
    }
}
