//! int8 row-wise / tensor-wise / column-wise quantization (paper eqs. 1–3).

use super::round_ties_even;
use crate::tensor::{Matrix, MatrixI8};

pub const INT8_MAX: f32 = 127.0;

/// absmax with the all-zero floor (matches `ref._safe_absmax`).
#[inline]
fn safe(m: f32) -> f32 {
    if m == 0.0 {
        1.0
    } else {
        m
    }
}

#[inline]
fn quantize_one(v: f32, scale: f32) -> i8 {
    round_ties_even(v * scale).clamp(-INT8_MAX, INT8_MAX) as i8
}

/// Row-wise quantized matrix: codes + per-row absmax state.
#[derive(Debug, Clone)]
pub struct QuantizedRow {
    pub codes: MatrixI8,
    pub state: Vec<f32>,
}

/// Tensor-wise quantized matrix: codes + scalar absmax state.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    pub codes: MatrixI8,
    pub state: f32,
}

/// Column-wise quantized matrix: codes + per-column absmax state.
#[derive(Debug, Clone)]
pub struct QuantizedCol {
    pub codes: MatrixI8,
    pub state: Vec<f32>,
}

/// Row-wise int8 quantization (paper eq. 1).
pub fn rowwise_quant(x: &Matrix) -> QuantizedRow {
    let mut codes = MatrixI8::zeros(x.rows, x.cols);
    let mut state = vec![0.0f32; x.rows];
    rowwise_quant_into(x, &mut codes, &mut state);
    QuantizedRow { codes, state }
}

/// In-place variant (the hot path reuses buffers; see EXPERIMENTS.md §Perf).
pub fn rowwise_quant_into(x: &Matrix, codes: &mut MatrixI8, state: &mut [f32]) {
    assert_eq!(codes.rows, x.rows);
    assert_eq!(codes.cols, x.cols);
    assert_eq!(state.len(), x.rows);
    for r in 0..x.rows {
        let row = x.row(r);
        let m = safe(row.iter().fold(0.0f32, |m, &v| m.max(v.abs())));
        state[r] = m;
        let scale = INT8_MAX / m;
        let crow = &mut codes.data[r * x.cols..(r + 1) * x.cols];
        for (c, &v) in crow.iter_mut().zip(row) {
            *c = quantize_one(v, scale);
        }
    }
}

/// Tensor-wise int8 quantization (paper eq. 2).
pub fn tensorwise_quant(x: &Matrix) -> QuantizedTensor {
    let m = safe(x.data.iter().fold(0.0f32, |m, &v| m.max(v.abs())));
    let scale = INT8_MAX / m;
    let mut codes = MatrixI8::zeros(x.rows, x.cols);
    for (c, &v) in codes.data.iter_mut().zip(&x.data) {
        *c = quantize_one(v, scale);
    }
    QuantizedTensor { codes, state: m }
}

/// Fused tensor-wise quantize + transpose (the paper's
/// `tensor-wise_quantize_transpose`, §2.2.1): output codes are `xᵀ`,
/// quantized in one pass over the input so memory is touched once.
pub fn tensorwise_quant_transpose(x: &Matrix) -> QuantizedTensor {
    let m = safe(x.data.iter().fold(0.0f32, |m, &v| m.max(v.abs())));
    let scale = INT8_MAX / m;
    let mut codes = MatrixI8::zeros(x.cols, x.rows);
    // Block the transpose for cache locality (same idea as the Pallas
    // kernel's VMEM-resident tile transpose).
    const B: usize = 64;
    for rb in (0..x.rows).step_by(B) {
        for cb in (0..x.cols).step_by(B) {
            for r in rb..(rb + B).min(x.rows) {
                let row = &x.data[r * x.cols..(r + 1) * x.cols];
                for c in cb..(cb + B).min(x.cols) {
                    codes.data[c * x.rows + r] = quantize_one(row[c], scale);
                }
            }
        }
    }
    QuantizedTensor { codes, state: m }
}

/// Column-wise int8 quantization (per-column state; LLM.int8() wgrad path).
pub fn colwise_quant(x: &Matrix) -> QuantizedCol {
    let mut maxes = vec![0.0f32; x.cols];
    for r in 0..x.rows {
        for (mx, &v) in maxes.iter_mut().zip(x.row(r)) {
            *mx = mx.max(v.abs());
        }
    }
    for m in maxes.iter_mut() {
        *m = safe(*m);
    }
    let mut codes = MatrixI8::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let crow = &mut codes.data[r * x.cols..(r + 1) * x.cols];
        for c in 0..x.cols {
            crow[c] = quantize_one(row[c], INT8_MAX / maxes[c]);
        }
    }
    QuantizedCol { codes, state: maxes }
}

/// Dequantize row-wise codes back to f32 (SwitchBackM backward path).
pub fn dequant_rowwise(q: &QuantizedRow) -> Matrix {
    let mut out = Matrix::zeros(q.codes.rows, q.codes.cols);
    for r in 0..q.codes.rows {
        let s = q.state[r] / INT8_MAX;
        let crow = q.codes.row(r);
        let orow = out.row_mut(r);
        for (o, &c) in orow.iter_mut().zip(crow) {
            *o = c as f32 * s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn rowwise_hits_full_range() {
        let x = Matrix::from_vec(2, 3, vec![1.0, -2.0, 0.5, 10.0, 5.0, -10.0]);
        let q = rowwise_quant(&x);
        assert_eq!(q.state, vec![2.0, 10.0]);
        // absmax element maps to ±127 exactly
        assert_eq!(q.codes.row(0)[1], -127);
        assert_eq!(q.codes.row(1)[0], 127);
        assert_eq!(q.codes.row(1)[2], -127);
    }

    #[test]
    fn zero_row_is_total() {
        let x = Matrix::zeros(3, 4);
        let q = rowwise_quant(&x);
        assert!(q.codes.data.iter().all(|&c| c == 0));
        assert!(q.state.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn dequant_error_bounded_by_half_step() {
        let mut rng = Rng::seed(5);
        let x = Matrix::randn(16, 32, 1.0, &mut rng);
        let q = rowwise_quant(&x);
        let back = dequant_rowwise(&q);
        for r in 0..x.rows {
            let step = q.state[r] / INT8_MAX;
            for c in 0..x.cols {
                assert!((x.at(r, c) - back.at(r, c)).abs() <= 0.5 * step + 1e-7);
            }
        }
    }

    #[test]
    fn quant_transpose_matches_quant_then_transpose() {
        let mut rng = Rng::seed(6);
        let x = Matrix::randn(33, 65, 2.0, &mut rng);
        let a = tensorwise_quant_transpose(&x);
        let b = tensorwise_quant(&x);
        assert_eq!(a.state, b.state);
        for r in 0..x.rows {
            for c in 0..x.cols {
                assert_eq!(a.codes.data[c * x.rows + r], b.codes.data[r * x.cols + c]);
            }
        }
    }

    #[test]
    fn colwise_state_per_column() {
        let x = Matrix::from_vec(2, 2, vec![1.0, 100.0, -3.0, 50.0]);
        let q = colwise_quant(&x);
        assert_eq!(q.state, vec![3.0, 100.0]);
        assert_eq!(q.codes.row(1)[0], -127);
        assert_eq!(q.codes.row(0)[1], 127);
    }
}
