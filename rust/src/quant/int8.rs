//! int8 row-wise / tensor-wise / column-wise quantization (paper eqs. 1–3).

use super::round_ties_even;
use crate::tensor::{Matrix, MatrixI8};

pub const INT8_MAX: f32 = 127.0;

/// absmax with the all-zero floor (matches `ref._safe_absmax`).
///
/// Shared with the packed GEMM's fused quantize+pack paths (`gemm::pack`)
/// so every quantizer in the crate applies the identical floor.
#[inline]
pub(crate) fn safe_absmax(m: f32) -> f32 {
    if m == 0.0 {
        1.0
    } else {
        m
    }
}

/// One value → one int8 code under an `INT8_MAX / absmax` scale.  Also
/// shared with `gemm::pack` (fused quantize+pack must emit the exact
/// same codes as quantize-then-pack).
#[inline]
pub(crate) fn quantize_one(v: f32, scale: f32) -> i8 {
    round_ties_even(v * scale).clamp(-INT8_MAX, INT8_MAX) as i8
}

/// Row-wise quantized matrix: codes + per-row absmax state.
#[derive(Debug, Clone)]
pub struct QuantizedRow {
    pub codes: MatrixI8,
    pub state: Vec<f32>,
}

/// Tensor-wise quantized matrix: codes + scalar absmax state.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    pub codes: MatrixI8,
    pub state: f32,
}

/// Column-wise quantized matrix: codes + per-column absmax state.
#[derive(Debug, Clone)]
pub struct QuantizedCol {
    pub codes: MatrixI8,
    pub state: Vec<f32>,
}

/// Which quantization statistic a matmul operand carries (paper §2.2.1):
/// the *scheme* as data, so [`crate::gemm::MatmulPlan`] can describe a
/// linear layer's precision strategy without per-kind code paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantScheme {
    /// per-row absmax (eq. 1) — activations / gradients.
    RowWise,
    /// scalar absmax (eq. 2) — SwitchBack weights.
    TensorWise,
    /// tensor-wise over `xᵀ`, fused quantize+transpose in one pass
    /// (§2.2.1) — the int8 dgrad's weight operand.
    TensorWiseTranspose,
    /// per-column absmax — LLM.int8()'s wgrad operand.
    ColWise,
}

/// A quantized matrix under any [`QuantScheme`].
#[derive(Debug, Clone)]
pub enum Quantized {
    Row(QuantizedRow),
    Tensor(QuantizedTensor),
    Col(QuantizedCol),
}

impl QuantScheme {
    pub fn label(&self) -> &'static str {
        match self {
            Self::RowWise => "rowwise",
            Self::TensorWise => "tensorwise",
            Self::TensorWiseTranspose => "tensorwise_transpose",
            Self::ColWise => "colwise",
        }
    }

    /// Quantize `x` under this scheme (allocating; the `*_into` variants
    /// below are the buffer-reuse forms the hot paths use).
    pub fn quantize(&self, x: &Matrix) -> Quantized {
        match self {
            Self::RowWise => Quantized::Row(rowwise_quant(x)),
            Self::TensorWise => Quantized::Tensor(tensorwise_quant(x)),
            Self::TensorWiseTranspose => {
                Quantized::Tensor(tensorwise_quant_transpose(x))
            }
            Self::ColWise => Quantized::Col(colwise_quant(x)),
        }
    }
}

/// Reusable row-wise quantization buffers: `rowwise(&x)` resizes (never
/// shrinking capacity) and overwrites, so a steady-state hot path — e.g.
/// the serving engine quantizing activations before every packed GEMM —
/// allocates nothing per call.  Keep one per thread (`gemm`'s
/// thread-local `ACT_SCRATCH`).
pub struct QuantScratch {
    q: QuantizedRow,
}

impl QuantScratch {
    pub fn new() -> Self {
        Self {
            q: QuantizedRow { codes: MatrixI8::zeros(0, 0), state: Vec::new() },
        }
    }

    /// Row-wise quantize `x` into the held buffers.
    pub fn rowwise(&mut self, x: &Matrix) -> &QuantizedRow {
        self.q.codes.rows = x.rows;
        self.q.codes.cols = x.cols;
        self.q.codes.data.resize(x.rows * x.cols, 0);
        self.q.state.resize(x.rows, 0.0);
        rowwise_quant_into(x, &mut self.q.codes, &mut self.q.state);
        &self.q
    }
}

impl Default for QuantScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Row-wise int8 quantization (paper eq. 1).
pub fn rowwise_quant(x: &Matrix) -> QuantizedRow {
    let mut codes = MatrixI8::zeros(x.rows, x.cols);
    let mut state = vec![0.0f32; x.rows];
    rowwise_quant_into(x, &mut codes, &mut state);
    QuantizedRow { codes, state }
}

/// One row's absmax (with the all-zero floor) + code emission — the
/// shared core of [`rowwise_quant_into`] and the packed GEMM's fused
/// row-quantize epilogue (`gemm::pack`), so a fused output row is
/// bit-identical to quantizing the materialized f32 row.  Returns the
/// row's state.
#[inline]
pub fn quantize_row_into(row: &[f32], codes: &mut [i8]) -> f32 {
    debug_assert_eq!(row.len(), codes.len());
    let m = safe_absmax(row.iter().fold(0.0f32, |m, &v| m.max(v.abs())));
    let scale = INT8_MAX / m;
    for (c, &v) in codes.iter_mut().zip(row) {
        *c = quantize_one(v, scale);
    }
    m
}

/// In-place variant (the hot path reuses buffers; see EXPERIMENTS.md §Perf).
pub fn rowwise_quant_into(x: &Matrix, codes: &mut MatrixI8, state: &mut [f32]) {
    assert_eq!(codes.rows, x.rows);
    assert_eq!(codes.cols, x.cols);
    assert_eq!(state.len(), x.rows);
    for r in 0..x.rows {
        let crow = &mut codes.data[r * x.cols..(r + 1) * x.cols];
        state[r] = quantize_row_into(x.row(r), crow);
    }
}

/// Tensor-wise int8 quantization (paper eq. 2).
pub fn tensorwise_quant(x: &Matrix) -> QuantizedTensor {
    let mut codes = MatrixI8::zeros(x.rows, x.cols);
    let state = tensorwise_quant_into(x, &mut codes);
    QuantizedTensor { codes, state }
}

/// In-place variant of [`tensorwise_quant`]; returns the scalar state.
pub fn tensorwise_quant_into(x: &Matrix, codes: &mut MatrixI8) -> f32 {
    assert_eq!(codes.rows, x.rows);
    assert_eq!(codes.cols, x.cols);
    let m = safe_absmax(x.data.iter().fold(0.0f32, |m, &v| m.max(v.abs())));
    let scale = INT8_MAX / m;
    for (c, &v) in codes.data.iter_mut().zip(&x.data) {
        *c = quantize_one(v, scale);
    }
    m
}

/// Fused tensor-wise quantize + transpose (the paper's
/// `tensor-wise_quantize_transpose`, §2.2.1): output codes are `xᵀ`,
/// quantized in one pass over the input so memory is touched once.
pub fn tensorwise_quant_transpose(x: &Matrix) -> QuantizedTensor {
    let mut codes = MatrixI8::zeros(x.cols, x.rows);
    let state = tensorwise_quant_transpose_into(x, &mut codes);
    QuantizedTensor { codes, state }
}

/// In-place variant of [`tensorwise_quant_transpose`] (`codes` must be
/// `[x.cols, x.rows]`); returns the scalar state.
pub fn tensorwise_quant_transpose_into(x: &Matrix, codes: &mut MatrixI8) -> f32 {
    assert_eq!(codes.rows, x.cols);
    assert_eq!(codes.cols, x.rows);
    let m = safe_absmax(x.data.iter().fold(0.0f32, |m, &v| m.max(v.abs())));
    let scale = INT8_MAX / m;
    // Block the transpose for cache locality (same idea as the Pallas
    // kernel's VMEM-resident tile transpose).
    const B: usize = 64;
    for rb in (0..x.rows).step_by(B) {
        for cb in (0..x.cols).step_by(B) {
            for r in rb..(rb + B).min(x.rows) {
                let row = &x.data[r * x.cols..(r + 1) * x.cols];
                for c in cb..(cb + B).min(x.cols) {
                    codes.data[c * x.rows + r] = quantize_one(row[c], scale);
                }
            }
        }
    }
    m
}

/// Column-wise int8 quantization (per-column state; LLM.int8() wgrad path).
pub fn colwise_quant(x: &Matrix) -> QuantizedCol {
    let mut codes = MatrixI8::zeros(x.rows, x.cols);
    let mut state = vec![0.0f32; x.cols];
    colwise_quant_into(x, &mut codes, &mut state);
    QuantizedCol { codes, state }
}

/// In-place variant of [`colwise_quant`] (`state` must be `x.cols` long).
pub fn colwise_quant_into(x: &Matrix, codes: &mut MatrixI8, state: &mut [f32]) {
    assert_eq!(codes.rows, x.rows);
    assert_eq!(codes.cols, x.cols);
    assert_eq!(state.len(), x.cols);
    for mx in state.iter_mut() {
        *mx = 0.0;
    }
    for r in 0..x.rows {
        for (mx, &v) in state.iter_mut().zip(x.row(r)) {
            *mx = mx.max(v.abs());
        }
    }
    for m in state.iter_mut() {
        *m = safe_absmax(*m);
    }
    for r in 0..x.rows {
        let row = x.row(r);
        let crow = &mut codes.data[r * x.cols..(r + 1) * x.cols];
        for c in 0..x.cols {
            crow[c] = quantize_one(row[c], INT8_MAX / state[c]);
        }
    }
}

/// Tensor-wise int8 round-trip statistics for live telemetry: the
/// relative L2 quantization error (`‖x − deq(quant(x))‖₂ / ‖x‖₂`) and
/// the clip rate (fraction of codes saturated at ±127 — the absmax
/// element always saturates, so a nonzero tensor's rate is ≥ 1/n).  One
/// streaming pass with no code buffer, cheap enough for the trainer's
/// probe cadence; these are the per-layer gauges the telemetry plane
/// exposes and a dynamic block-level fallback policy would consume.
pub fn tensorwise_quant_stats(x: &[f32]) -> (f32, f32) {
    if x.is_empty() {
        return (0.0, 0.0);
    }
    let absmax = safe_absmax(x.iter().fold(0.0f32, |m, v| m.max(v.abs())));
    let scale = INT8_MAX / absmax;
    let step = absmax / INT8_MAX;
    let mut err_ss = 0.0f64;
    let mut x_ss = 0.0f64;
    let mut clipped = 0usize;
    for &v in x {
        let q = quantize_one(v, scale);
        if q == 127 || q == -127 {
            clipped += 1;
        }
        let d = (v - q as f32 * step) as f64;
        err_ss += d * d;
        x_ss += (v as f64) * (v as f64);
    }
    let rel = if x_ss > 0.0 { (err_ss / x_ss).sqrt() as f32 } else { 0.0 };
    (rel, clipped as f32 / x.len() as f32)
}

/// Dequantize row-wise codes back to f32 (SwitchBackM backward path).
pub fn dequant_rowwise(q: &QuantizedRow) -> Matrix {
    let mut out = Matrix::zeros(q.codes.rows, q.codes.cols);
    for r in 0..q.codes.rows {
        let s = q.state[r] / INT8_MAX;
        let crow = q.codes.row(r);
        let orow = out.row_mut(r);
        for (o, &c) in orow.iter_mut().zip(crow) {
            *o = c as f32 * s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn rowwise_hits_full_range() {
        let x = Matrix::from_vec(2, 3, vec![1.0, -2.0, 0.5, 10.0, 5.0, -10.0]);
        let q = rowwise_quant(&x);
        assert_eq!(q.state, vec![2.0, 10.0]);
        // absmax element maps to ±127 exactly
        assert_eq!(q.codes.row(0)[1], -127);
        assert_eq!(q.codes.row(1)[0], 127);
        assert_eq!(q.codes.row(1)[2], -127);
    }

    #[test]
    fn zero_row_is_total() {
        let x = Matrix::zeros(3, 4);
        let q = rowwise_quant(&x);
        assert!(q.codes.data.iter().all(|&c| c == 0));
        assert!(q.state.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn dequant_error_bounded_by_half_step() {
        let mut rng = Rng::seed(5);
        let x = Matrix::randn(16, 32, 1.0, &mut rng);
        let q = rowwise_quant(&x);
        let back = dequant_rowwise(&q);
        for r in 0..x.rows {
            let step = q.state[r] / INT8_MAX;
            for c in 0..x.cols {
                assert!((x.at(r, c) - back.at(r, c)).abs() <= 0.5 * step + 1e-7);
            }
        }
    }

    #[test]
    fn quant_stats_error_and_clip_rate() {
        // exactly representable tensor: absmax 1.27, codes step 0.01
        let x = vec![1.27, -1.27, 0.0, 0.64];
        let (err, clip) = tensorwise_quant_stats(&x);
        // 0.64 → 64 codes exactly; everything round-trips with tiny error
        assert!(err < 1e-3, "err {err}");
        assert!((clip - 0.5).abs() < 1e-6, "clip {clip}"); // the two ±absmax
        // all-zero tensor: no error, nothing saturates (absmax floor = 1.0)
        assert_eq!(tensorwise_quant_stats(&[0.0; 8]), (0.0, 0.0));
        assert_eq!(tensorwise_quant_stats(&[]), (0.0, 0.0));
        // a heavy-tailed tensor has a real relative error, bounded by the
        // half-step of its own scale
        let mut rng = Rng::seed(9);
        let m = Matrix::randn(8, 64, 1.0, &mut rng);
        let (err, clip) = tensorwise_quant_stats(&m.data);
        assert!(err > 0.0 && err < 0.05, "err {err}");
        assert!(clip >= 1.0 / 512.0 && clip < 0.1, "clip {clip}");
    }

    #[test]
    fn quant_transpose_matches_quant_then_transpose() {
        let mut rng = Rng::seed(6);
        let x = Matrix::randn(33, 65, 2.0, &mut rng);
        let a = tensorwise_quant_transpose(&x);
        let b = tensorwise_quant(&x);
        assert_eq!(a.state, b.state);
        for r in 0..x.rows {
            for c in 0..x.cols {
                assert_eq!(a.codes.data[c * x.rows + r], b.codes.data[r * x.cols + c]);
            }
        }
    }

    #[test]
    fn colwise_state_per_column() {
        let x = Matrix::from_vec(2, 2, vec![1.0, 100.0, -3.0, 50.0]);
        let q = colwise_quant(&x);
        assert_eq!(q.state, vec![3.0, 100.0]);
        assert_eq!(q.codes.row(1)[0], -127);
        assert_eq!(q.codes.row(0)[1], 127);
    }

    /// Every `_into` variant must reproduce its allocating twin exactly
    /// (the hot paths depend on buffer reuse changing nothing).
    #[test]
    fn into_variants_match_allocating_forms() {
        let mut rng = Rng::seed(7);
        let x = Matrix::randn(13, 21, 1.5, &mut rng);
        let mut codes = MatrixI8::zeros(13, 21);
        let mut state = vec![0.0f32; 13];
        rowwise_quant_into(&x, &mut codes, &mut state);
        let q = rowwise_quant(&x);
        assert_eq!(codes.data, q.codes.data);
        assert_eq!(state, q.state);

        let mut tc = MatrixI8::zeros(13, 21);
        assert_eq!(tensorwise_quant_into(&x, &mut tc), tensorwise_quant(&x).state);
        assert_eq!(tc.data, tensorwise_quant(&x).codes.data);

        let mut tt = MatrixI8::zeros(21, 13);
        let st = tensorwise_quant_transpose_into(&x, &mut tt);
        let qt = tensorwise_quant_transpose(&x);
        assert_eq!(st, qt.state);
        assert_eq!(tt.data, qt.codes.data);

        let mut cc = MatrixI8::zeros(13, 21);
        let mut cs = vec![9.0f32; 21]; // stale values must be overwritten
        colwise_quant_into(&x, &mut cc, &mut cs);
        let qc = colwise_quant(&x);
        assert_eq!(cc.data, qc.codes.data);
        assert_eq!(cs, qc.state);
    }

    #[test]
    fn scheme_dispatch_matches_direct_calls() {
        let mut rng = Rng::seed(8);
        let x = Matrix::randn(9, 17, 1.0, &mut rng);
        match QuantScheme::RowWise.quantize(&x) {
            Quantized::Row(q) => assert_eq!(q.codes.data, rowwise_quant(&x).codes.data),
            _ => panic!("wrong variant"),
        }
        match QuantScheme::TensorWiseTranspose.quantize(&x) {
            Quantized::Tensor(q) => {
                assert_eq!(q.codes.data, tensorwise_quant_transpose(&x).codes.data)
            }
            _ => panic!("wrong variant"),
        }
        assert_eq!(QuantScheme::ColWise.label(), "colwise");
    }

    /// The scratch reuses buffers across shape changes without leaking
    /// stale codes or state.
    #[test]
    fn quant_scratch_reuse_is_exact() {
        let mut rng = Rng::seed(9);
        let mut scratch = QuantScratch::new();
        for (r, c) in [(8, 32), (3, 5), (16, 64)] {
            let x = Matrix::randn(r, c, 1.0, &mut rng);
            let q = scratch.rowwise(&x);
            let fresh = rowwise_quant(&x);
            assert_eq!(q.codes.rows, r);
            assert_eq!(q.codes.cols, c);
            assert_eq!(q.codes.data[..r * c], fresh.codes.data[..]);
            assert_eq!(q.state[..r], fresh.state[..]);
        }
    }
}
