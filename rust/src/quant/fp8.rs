//! Exact float8 value simulation (E4M3 / E5M2), mirroring
//! `python/compile/kernels/fp8.py` (which is itself validated bit-exactly
//! against ml_dtypes).  Round-to-nearest-even onto the fp8 grid, including
//! subnormals and saturation — the paper's §2.2.1 "float8cast".

/// A float8 format description (same fields as the python dataclass).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fp8Format {
    pub name: &'static str,
    pub mantissa_bits: i32,
    pub min_normal_exp: i32,
    pub max_value: f32,
}

/// E4M3 ("fn" flavour): max 448, min normal 2⁻⁶, subnormal quantum 2⁻⁹.
pub const E4M3: Fp8Format = Fp8Format {
    name: "e4m3",
    mantissa_bits: 3,
    min_normal_exp: -6,
    max_value: 448.0,
};

/// E5M2: max finite 57344, min normal 2⁻¹⁴, subnormal quantum 2⁻¹⁶.
pub const E5M2: Fp8Format = Fp8Format {
    name: "e5m2",
    mantissa_bits: 2,
    min_normal_exp: -14,
    max_value: 57344.0,
};

/// Round one f32 to the nearest fp8-representable value (saturating).
pub fn fp8_round(x: f32, fmt: Fp8Format) -> f32 {
    if x == 0.0 || !x.is_finite() {
        // NaN propagates; ±inf saturates (fn-flavoured formats are finite).
        if x.is_infinite() {
            return x.signum() * fmt.max_value;
        }
        return x;
    }
    let a = x.abs();
    // floor(log2(a)) via the exponent bits (exact, unlike log2f).
    let bits = a.to_bits();
    let mut e = ((bits >> 23) & 0xFF) as i32 - 127;
    if (bits >> 23) & 0xFF == 0 {
        // f32 subnormal input — far below fp8 min subnormal; clamp exponent.
        e = -127;
    }
    let e = e.max(fmt.min_normal_exp);
    let quantum = (2.0f32).powi(e - fmt.mantissa_bits);
    let q = (a / quantum).round_ties_even() * quantum;
    let q = q.min(fmt.max_value);
    x.signum() * q
}

/// Round a slice in place.
pub fn fp8_round_slice(xs: &mut [f32], fmt: Fp8Format) {
    for v in xs.iter_mut() {
        *v = fp8_round(*v, fmt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// All 126 positive finite E4M3 values by direct enumeration.
    fn e4m3_grid() -> Vec<f32> {
        let mut vals = vec![];
        // subnormals: m * 2^-9, m in 1..8
        for m in 1..8 {
            vals.push(m as f32 * 2.0f32.powi(-9));
        }
        // normals: (1 + m/8) * 2^e, e in -6..=8, skipping codes above 448
        for e in -6..=8 {
            for m in 0..8 {
                let v = (1.0 + m as f32 / 8.0) * 2.0f32.powi(e);
                if v <= 448.0 {
                    vals.push(v);
                }
            }
        }
        vals
    }

    #[test]
    fn grid_points_are_fixed() {
        for v in e4m3_grid() {
            assert_eq!(fp8_round(v, E4M3), v, "grid point {v} must be exact");
            assert_eq!(fp8_round(-v, E4M3), -v);
        }
    }

    #[test]
    fn rounds_to_nearest_with_ties_even() {
        // Between 1.0 and 1.125 the midpoint 1.0625 ties to even (1.0).
        assert_eq!(fp8_round(1.0625, E4M3), 1.0);
        // Between 1.125 and 1.25 midpoint 1.1875 ties to even (1.25).
        assert_eq!(fp8_round(1.1875, E4M3), 1.25);
        assert_eq!(fp8_round(1.06, E4M3), 1.0);
        assert_eq!(fp8_round(1.07, E4M3), 1.125);
    }

    #[test]
    fn saturates() {
        assert_eq!(fp8_round(1e6, E4M3), 448.0);
        assert_eq!(fp8_round(-1e6, E4M3), -448.0);
        assert_eq!(fp8_round(f32::INFINITY, E4M3), 448.0);
        assert_eq!(fp8_round(1e9, E5M2), 57344.0);
    }

    #[test]
    fn subnormal_handling() {
        let q = 2.0f32.powi(-9); // E4M3 subnormal quantum
        assert_eq!(fp8_round(q, E4M3), q);
        assert_eq!(fp8_round(q * 0.4, E4M3), 0.0); // rounds down to zero
        assert_eq!(fp8_round(q * 0.6, E4M3), q);
        assert_eq!(fp8_round(0.0, E4M3), 0.0);
    }

    #[test]
    fn monotone_nondecreasing() {
        let mut prev = f32::NEG_INFINITY;
        let mut x = -500.0f32;
        while x < 500.0 {
            let r = fp8_round(x, E4M3);
            assert!(r >= prev, "non-monotone at {x}: {r} < {prev}");
            prev = r;
            x += 0.37;
        }
    }

    #[test]
    fn e5m2_normals() {
        assert_eq!(fp8_round(3.0, E5M2), 3.0); // 1.5*2 representable with 2 bits
        assert_eq!(fp8_round(3.1, E5M2), 3.0);
        assert_eq!(fp8_round(3.3, E5M2), 3.5);
    }

    /// NaN propagates through the cast (the paper's float8 simulation must
    /// surface divergence, not mask it), for both formats and both sign
    /// bits of the payload.
    #[test]
    fn nan_propagates() {
        for fmt in [E4M3, E5M2] {
            assert!(fp8_round(f32::NAN, fmt).is_nan(), "{}", fmt.name);
            assert!(fp8_round(-f32::NAN, fmt).is_nan(), "{}", fmt.name);
        }
        // and through the slice path, leaving neighbours untouched
        let mut xs = [1.0f32, f32::NAN, -2.0];
        fp8_round_slice(&mut xs, E4M3);
        assert_eq!(xs[0], 1.0);
        assert!(xs[1].is_nan());
        assert_eq!(xs[2], -2.0);
    }

    /// ±Inf saturates to ±max (fn-flavoured formats are finite), and the
    /// saturation boundary is half-way between the last two grid points.
    #[test]
    fn infinity_and_saturation_boundaries() {
        for (fmt, max) in [(E4M3, 448.0f32), (E5M2, 57344.0)] {
            assert_eq!(fp8_round(f32::INFINITY, fmt), max, "{}", fmt.name);
            assert_eq!(fp8_round(f32::NEG_INFINITY, fmt), -max, "{}", fmt.name);
            assert_eq!(fp8_round(max, fmt), max);
            assert_eq!(fp8_round(f32::MAX, fmt), max);
            // one ulp above max still saturates rather than escaping the grid
            assert_eq!(fp8_round(max * 1.001, fmt), max);
        }
    }

    /// E5M2 subnormals: quantum 2⁻¹⁶ below the 2⁻¹⁴ min normal; round to
    /// nearest with ties-to-even on the subnormal grid.
    #[test]
    fn e5m2_subnormal_grid() {
        let q = 2.0f32.powi(-16);
        for m in 1..4 {
            let v = m as f32 * q;
            assert_eq!(fp8_round(v, E5M2), v, "subnormal grid point {m}");
            assert_eq!(fp8_round(-v, E5M2), -v);
        }
        assert_eq!(fp8_round(q * 0.4, E5M2), 0.0);
        assert_eq!(fp8_round(q * 0.6, E5M2), q);
        // tie at 0.5·q goes to even (0); tie at 1.5·q goes to even (2q)
        assert_eq!(fp8_round(q * 0.5, E5M2), 0.0);
        assert_eq!(fp8_round(q * 1.5, E5M2), 2.0 * q);
        // min normal boundary is exact
        assert_eq!(fp8_round(2.0f32.powi(-14), E5M2), 2.0f32.powi(-14));
    }

    /// f32 inputs that are *themselves* subnormal (< 2⁻¹²⁶) are far below
    /// either format's smallest subnormal and must flush to ±0, preserving
    /// nothing but the sign.
    #[test]
    fn f32_subnormal_inputs_flush_to_zero() {
        let tiny = f32::from_bits(1); // smallest positive f32 subnormal
        for fmt in [E4M3, E5M2] {
            assert_eq!(fp8_round(tiny, fmt), 0.0, "{}", fmt.name);
            assert_eq!(fp8_round(-tiny, fmt), 0.0, "{}", fmt.name);
            assert_eq!(fp8_round(f32::MIN_POSITIVE / 2.0, fmt), 0.0);
        }
    }

    /// The cast is idempotent: round(round(x)) == round(x) across normals,
    /// subnormals, saturated values and signed zeros — i.e. every output
    /// is a fixed point of the grid (a round-trip property the fp8
    /// training simulation relies on every step).
    #[test]
    fn round_trip_is_idempotent() {
        for fmt in [E4M3, E5M2] {
            let mut probes: Vec<f32> = vec![0.0, -0.0, 1e-30, -1e-30, 1e30, -1e30];
            let mut x = -600.0f32;
            while x < 600.0 {
                probes.push(x);
                x += 0.618;
            }
            // dense sweep through the subnormal band too
            for m in 0..40 {
                probes.push(m as f32 * 2.0f32.powi(-18));
            }
            for &p in &probes {
                let once = fp8_round(p, fmt);
                let twice = fp8_round(once, fmt);
                assert_eq!(
                    once.to_bits(),
                    twice.to_bits(),
                    "{}: {p} → {once} → {twice} not idempotent",
                    fmt.name
                );
            }
        }
    }
}
