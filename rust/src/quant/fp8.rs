//! Exact float8 value simulation (E4M3 / E5M2), mirroring
//! `python/compile/kernels/fp8.py` (which is itself validated bit-exactly
//! against ml_dtypes).  Round-to-nearest-even onto the fp8 grid, including
//! subnormals and saturation — the paper's §2.2.1 "float8cast".

/// A float8 format description (same fields as the python dataclass).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fp8Format {
    pub name: &'static str,
    pub mantissa_bits: i32,
    pub min_normal_exp: i32,
    pub max_value: f32,
}

/// E4M3 ("fn" flavour): max 448, min normal 2⁻⁶, subnormal quantum 2⁻⁹.
pub const E4M3: Fp8Format = Fp8Format {
    name: "e4m3",
    mantissa_bits: 3,
    min_normal_exp: -6,
    max_value: 448.0,
};

/// E5M2: max finite 57344, min normal 2⁻¹⁴, subnormal quantum 2⁻¹⁶.
pub const E5M2: Fp8Format = Fp8Format {
    name: "e5m2",
    mantissa_bits: 2,
    min_normal_exp: -14,
    max_value: 57344.0,
};

/// Round one f32 to the nearest fp8-representable value (saturating).
pub fn fp8_round(x: f32, fmt: Fp8Format) -> f32 {
    if x == 0.0 || !x.is_finite() {
        // NaN propagates; ±inf saturates (fn-flavoured formats are finite).
        if x.is_infinite() {
            return x.signum() * fmt.max_value;
        }
        return x;
    }
    let a = x.abs();
    // floor(log2(a)) via the exponent bits (exact, unlike log2f).
    let bits = a.to_bits();
    let mut e = ((bits >> 23) & 0xFF) as i32 - 127;
    if (bits >> 23) & 0xFF == 0 {
        // f32 subnormal input — far below fp8 min subnormal; clamp exponent.
        e = -127;
    }
    let e = e.max(fmt.min_normal_exp);
    let quantum = (2.0f32).powi(e - fmt.mantissa_bits);
    let q = (a / quantum).round_ties_even() * quantum;
    let q = q.min(fmt.max_value);
    x.signum() * q
}

/// Round a slice in place.
pub fn fp8_round_slice(xs: &mut [f32], fmt: Fp8Format) {
    for v in xs.iter_mut() {
        *v = fp8_round(*v, fmt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// All 126 positive finite E4M3 values by direct enumeration.
    fn e4m3_grid() -> Vec<f32> {
        let mut vals = vec![];
        // subnormals: m * 2^-9, m in 1..8
        for m in 1..8 {
            vals.push(m as f32 * 2.0f32.powi(-9));
        }
        // normals: (1 + m/8) * 2^e, e in -6..=8, skipping codes above 448
        for e in -6..=8 {
            for m in 0..8 {
                let v = (1.0 + m as f32 / 8.0) * 2.0f32.powi(e);
                if v <= 448.0 {
                    vals.push(v);
                }
            }
        }
        vals
    }

    #[test]
    fn grid_points_are_fixed() {
        for v in e4m3_grid() {
            assert_eq!(fp8_round(v, E4M3), v, "grid point {v} must be exact");
            assert_eq!(fp8_round(-v, E4M3), -v);
        }
    }

    #[test]
    fn rounds_to_nearest_with_ties_even() {
        // Between 1.0 and 1.125 the midpoint 1.0625 ties to even (1.0).
        assert_eq!(fp8_round(1.0625, E4M3), 1.0);
        // Between 1.125 and 1.25 midpoint 1.1875 ties to even (1.25).
        assert_eq!(fp8_round(1.1875, E4M3), 1.25);
        assert_eq!(fp8_round(1.06, E4M3), 1.0);
        assert_eq!(fp8_round(1.07, E4M3), 1.125);
    }

    #[test]
    fn saturates() {
        assert_eq!(fp8_round(1e6, E4M3), 448.0);
        assert_eq!(fp8_round(-1e6, E4M3), -448.0);
        assert_eq!(fp8_round(f32::INFINITY, E4M3), 448.0);
        assert_eq!(fp8_round(1e9, E5M2), 57344.0);
    }

    #[test]
    fn subnormal_handling() {
        let q = 2.0f32.powi(-9); // E4M3 subnormal quantum
        assert_eq!(fp8_round(q, E4M3), q);
        assert_eq!(fp8_round(q * 0.4, E4M3), 0.0); // rounds down to zero
        assert_eq!(fp8_round(q * 0.6, E4M3), q);
        assert_eq!(fp8_round(0.0, E4M3), 0.0);
    }

    #[test]
    fn monotone_nondecreasing() {
        let mut prev = f32::NEG_INFINITY;
        let mut x = -500.0f32;
        while x < 500.0 {
            let r = fp8_round(x, E4M3);
            assert!(r >= prev, "non-monotone at {x}: {r} < {prev}");
            prev = r;
            x += 0.37;
        }
    }

    #[test]
    fn e5m2_normals() {
        assert_eq!(fp8_round(3.0, E5M2), 3.0); // 1.5*2 representable with 2 bits
        assert_eq!(fp8_round(3.1, E5M2), 3.0);
        assert_eq!(fp8_round(3.3, E5M2), 3.5);
    }
}
