//! Quantization primitives — the rust mirror of the L1 kernels.
//!
//! Definitions match `python/compile/kernels/ref.py` bit-for-bit (int8
//! codes) — cross-checked against golden vectors generated from the jnp
//! oracles (`rust/tests/golden.rs`).  These primitives feed:
//!
//! * the native [`crate::gemm`] speed substrate (Fig 3/4/13),
//! * the Appendix-C quantization-variance experiment,
//! * property tests on quantization invariants.
//!
//! Paper conventions (§2.2.1): row-wise quantization (eq. 1) keeps a
//! per-row absmax *state*; tensor-wise (eq. 2) keeps a scalar.  Dequantize
//! multiplies by `state/127` per side (eq. 3).

mod fp8;
mod int8;

pub use fp8::{fp8_round, fp8_round_slice, Fp8Format, E4M3, E5M2};
pub use int8::{
    colwise_quant, colwise_quant_into, dequant_rowwise, quantize_row_into,
    rowwise_quant, rowwise_quant_into, tensorwise_quant, tensorwise_quant_into,
    tensorwise_quant_stats, tensorwise_quant_transpose,
    tensorwise_quant_transpose_into, QuantScheme,
    QuantScratch, Quantized, QuantizedCol, QuantizedRow, QuantizedTensor,
    INT8_MAX,
};
pub(crate) use int8::{quantize_one, safe_absmax};

/// Round-half-to-even for f32, matching `jnp.round` / IEEE
/// round-to-nearest-even (std's `f32::round` rounds half away from zero,
/// which would diverge from the oracle on exact .5 codes).
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    // `f32::round_ties_even` is stable since 1.77.
    x.round_ties_even()
}

/// bf16 rounding (round-to-nearest-even on the top 16 bits) — used by the
/// "16-bit baseline" bookkeeping and tests.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    crate::util::float::bf16_round(x)
}

/// fp16 rounding + range behaviour — used by the §3.6 loss-scaler
/// simulation (values beyond ±65504 overflow to ±inf exactly as fp16 does).
#[inline]
pub fn fp16_round(x: f32) -> f32 {
    crate::util::float::fp16_round(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ties_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
    }

    #[test]
    fn fp16_overflow_is_inf() {
        assert!(fp16_round(70000.0).is_infinite());
        assert!(fp16_round(65504.0).is_finite());
    }

    #[test]
    fn bf16_roundtrip_coarse() {
        // bf16 has 8 mantissa bits: 1.0 + 2^-9 rounds back to 1.0
        assert_eq!(bf16_round(1.0 + 2.0_f32.powi(-9)), 1.0);
        assert_eq!(bf16_round(1.0 + 2.0_f32.powi(-7)), 1.0 + 2.0_f32.powi(-7));
    }
}
