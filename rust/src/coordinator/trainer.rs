//! The training loop — where L1/L2 artifacts, the rust optimizer, the
//! loss scalers and the stability telemetry all compose.
//!
//! Per step:
//! 1. synthesize the next batch ([`crate::data`], honouring the shift
//!    schedule),
//! 2. execute the AOT train-step (loss + grads + feature magnitudes),
//! 3. run the loss-scaler policy (§3.6) on the (simulated-fp16) grads,
//! 4. optionally clip the global gradient norm (Fig 10 baseline),
//! 5. step the optimizer (AdamW / StableAdamW / Lion) with the schedule's
//!    LR, collecting per-tensor `RMS_t`,
//! 6. log everything to the metrics sink (the figures regenerate from
//!    these logs).

use crate::config::{ScalerKind, TrainConfig};
use crate::coordinator::common::{build_optimizer, tail_mean_loss};
use crate::coordinator::eval::zero_shot_accuracy;
use crate::data::{DataConfig, SyntheticClip};
use crate::optim::scaler::{DynamicGlobalScaler, FixedTensorScaler, ScaleDecision};
use crate::optim::schedules::LrSchedule;
use crate::optim::{clip_global_norm, Optimizer};
use crate::runtime::{Artifact, Runtime};
use crate::telemetry::{MetricsSink, StepRecord, TensorProbe};
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;

/// Outcome of a full run.
pub struct RunResult {
    pub config: TrainConfig,
    pub final_loss: f32,
    /// mean loss over the last 10% of steps (the robust curve endpoint)
    pub tail_loss: f32,
    pub zero_shot_acc: Option<f32>,
    pub diverged: bool,
    pub sink: MetricsSink,
    /// names of the probed tensors: (patch_embed, mid control)
    pub probe_names: (String, String),
    pub steps_per_sec: f32,
    /// feature magnitudes at init and at the end (Fig 5 right)
    pub mags_first: Vec<f32>,
    pub mags_last: Vec<f32>,
}

impl RunResult {
    pub fn loss_trace(&self) -> Vec<f32> {
        self.sink.loss_trace()
    }
}

/// Trainer over one artifact.  The artifact is behind an `Rc` so sweep
/// runners can reuse one compiled executable across many runs (compiling
/// the HLO dominates short-run wall time — see EXPERIMENTS.md §Perf).
pub struct Trainer<'rt> {
    runtime: &'rt Runtime,
    artifact: std::rc::Rc<Artifact>,
    cfg: TrainConfig,
}

impl<'rt> Trainer<'rt> {
    pub fn new(runtime: &'rt Runtime, cfg: TrainConfig) -> Result<Self> {
        let artifact =
            std::rc::Rc::new(runtime.load(Path::new(&cfg.artifact_dir), &cfg.artifact)?);
        Ok(Self { runtime, artifact, cfg })
    }

    /// Reuse an already-compiled artifact (sweep path).
    pub fn with_artifact(
        runtime: &'rt Runtime,
        artifact: std::rc::Rc<Artifact>,
        cfg: TrainConfig,
    ) -> Self {
        Self { runtime, artifact, cfg }
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    fn build_optimizer(&self, sizes: &[usize]) -> Box<dyn Optimizer> {
        let metas = self.artifact.param_metas();
        build_optimizer(&self.cfg.hyper(), &metas, sizes)
    }

    /// Run the configured number of steps.  `verbose` prints a progress
    /// line every ~20 steps.
    pub fn run(&mut self, verbose: bool) -> Result<RunResult> {
        let m = &self.artifact.manifest;
        let mut data = SyntheticClip::new(DataConfig {
            shifts: self.cfg.shifts.clone(),
            ..DataConfig::for_model(
                m.config.patches,
                m.config.patch_dim,
                m.config.seq,
                m.config.vocab,
                self.cfg.seed.wrapping_add(0x5EED),
            )
        });
        let mut params =
            self.artifact.initial_params(self.cfg.seed, self.cfg.reinit)?;
        let sizes: Vec<usize> = params.iter().map(|p| p.len()).collect();
        let mut opt = self.build_optimizer(&sizes);
        let schedule =
            LrSchedule::new(self.cfg.lr, self.cfg.warmup, self.cfg.steps);
        let (pe_idx, mid_idx) = self.artifact.probe_indices();
        let pe_name = m.tensors[pe_idx].name.clone();
        let mid_name = m.tensors[mid_idx].name.clone();

        let mut sink = match &self.cfg.metrics_path {
            Some(p) => MetricsSink::to_file(Path::new(p))?,
            None => MetricsSink::memory(),
        };
        let mut dyn_scaler = DynamicGlobalScaler::new();
        let mut fix_scaler = FixedTensorScaler::new(65536.0, params.len());
        let batch_size = self.artifact.batch();
        let mut mags_first: Vec<f32> = vec![];
        let mut mags_last: Vec<f32> = vec![];
        let mut diverged = false;
        let t0 = crate::trace::clock();

        for step in 1..=self.cfg.steps {
            let batch = data.next_batch(batch_size);
            let out =
                self.artifact.train_step(&params, &batch.images, &batch.tokens)?;
            if mags_first.is_empty() {
                mags_first = out.mags.clone();
            }
            mags_last = out.mags.clone();
            let mut grads = out.grads;
            if !out.loss.is_finite() || out.loss > 50.0 {
                diverged = true;
            }

            // §3.6 loss-scaler policy on simulated-fp16 gradients.
            let (decision, scale) = match self.cfg.scaler {
                ScalerKind::None => (ScaleDecision::Proceed, None),
                ScalerKind::DynamicGlobal => {
                    let d = dyn_scaler.inspect(&grads);
                    (d, Some(dyn_scaler.scale))
                }
                ScalerKind::FixedTensor => {
                    let d = fix_scaler.inspect(&grads);
                    (d, Some(fix_scaler.scale))
                }
            };

            let grad_norm = {
                let mut ss = 0.0f64;
                for g in &grads {
                    for &v in g {
                        if v.is_finite() {
                            ss += (v as f64) * (v as f64);
                        }
                    }
                }
                ss.sqrt() as f32
            };
            if let Some(max_norm) = self.cfg.grad_clip {
                clip_global_norm(&mut grads, max_norm);
            }

            let lr = schedule.at(step);
            let mut rec = StepRecord {
                step,
                loss: out.loss,
                lr,
                grad_norm,
                loss_scale: scale,
                ..Default::default()
            };
            match decision {
                ScaleDecision::Proceed => {
                    let stats = opt.step(&mut params, &grads, lr, None);
                    rec.rms.insert(pe_name.clone(), stats.rms[pe_idx]);
                    rec.rms.insert(mid_name.clone(), stats.rms[mid_idx]);
                }
                ScaleDecision::SkipStep => {
                    rec.skipped_step = true;
                }
                ScaleDecision::SkipTensors(mask) => {
                    let stats = opt.step(&mut params, &grads, lr, Some(&mask));
                    rec.skipped_tensors = stats.skipped_tensors;
                    rec.rms.insert(pe_name.clone(), stats.rms[pe_idx]);
                    rec.rms.insert(mid_name.clone(), stats.rms[mid_idx]);
                }
            }
            if self.cfg.probe_every > 0 && step % self.cfg.probe_every == 0 {
                rec.feature_mags = out.mags.clone();
                let mut probes = BTreeMap::new();
                probes.insert(pe_name.clone(), TensorProbe::of(&grads[pe_idx]));
                probes.insert(mid_name.clone(), TensorProbe::of(&grads[mid_idx]));
                rec.grad_probes = probes;
            }
            if verbose && (step % 20 == 0 || step == 1) {
                println!(
                    "  step {step:>5}  loss {:8.4}  lr {:.2e}  |g| {:8.3}",
                    out.loss, lr, grad_norm
                );
            }
            sink.log(rec);
        }
        let elapsed = t0.elapsed().as_secs_f32();

        // Final zero-shot-style evaluation (if an encode artifact exists).
        let zero_shot_acc = if self.artifact.manifest.encode_hlo.is_some() {
            Some(zero_shot_accuracy(
                &self.artifact,
                &params,
                &data,
                self.cfg.eval_per_concept,
            )?)
        } else {
            None
        };

        let losses = sink.loss_trace();
        let tail_loss = tail_mean_loss(&losses);
        Ok(RunResult {
            config: self.cfg.clone(),
            final_loss: *losses.last().unwrap_or(&f32::NAN),
            tail_loss,
            zero_shot_acc,
            diverged,
            sink,
            probe_names: (pe_name, mid_name),
            steps_per_sec: self.cfg.steps as f32 / elapsed.max(1e-9),
            mags_first,
            mags_last,
        })
    }

    pub fn runtime(&self) -> &Runtime {
        self.runtime
    }
}
