//! The experiment registry, shared between the PJRT figure experiments
//! (`coordinator::experiments`, feature `pjrt`) and the native training
//! scenarios (`crate::train`, no feature).
//!
//! Keeping the *listing* un-gated means `switchback help`-adjacent
//! surfaces (and docs generated from them) show the full experiment
//! catalogue even in offline builds, and the two paths cannot drift into
//! separately-maintained name tables.

/// One registry entry: a runnable experiment or scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExpEntry {
    pub name: &'static str,
    pub desc: &'static str,
    /// true ⇒ needs the PJRT runtime + AOT artifacts (`exp` subcommand);
    /// false ⇒ runs on the native substrate (`train` subcommand).
    pub needs_pjrt: bool,
}

/// The paper-figure experiments (run via `switchback exp`, feature `pjrt`).
pub fn figure_experiments() -> Vec<ExpEntry> {
    let f = |name, desc| ExpEntry { name, desc, needs_pjrt: true };
    vec![
        f("fig1-int8", "zero-shot acc vs scale: bf16 vs LLM.int8 vs SwitchBack (int8)"),
        f("fig1-fp8", "zero-shot acc vs scale: bf16 vs tensor-wise fp8 vs SwitchBack (fp8)"),
        f("fig2", "loss curves for the fig1 runs (reads fig1 logs)"),
        f(
            "fig5-divergence",
            "fp8 tensor-wise rescue attempts: gradclip / kq-norm / zero-init layer-scale",
        ),
        f("fig5-magnitude", "per-block feature magnitudes, init vs end, ± layer-scale"),
        f("fig6", "loss spikes vs MODEL SIZE × β2"),
        f("fig7", "loss spikes vs BATCH SIZE × β2"),
        f("fig8", "loss spikes vs LEARNING RATE × β2"),
        f("fig9", "RMS_t spikes precede loss spikes (patch embedding)"),
        f("fig10", "StableAdamW vs gradient clipping vs β2 (loss + accuracy)"),
        f("fig11", "loss spikes co-occur with activation/grad spikes + scaler drops"),
        f("fig14", "gradient/activation mean+max through training, ± layer-scale"),
        f("fig15", "β2 warmup schedule 1−t^−λ does not help"),
        f("fig16", "lead-lag statistics pooled over β2 (larger model)"),
        f("fig17", "lead-lag statistics pooled over β2 (smaller model)"),
        f("fig21", "control: mid-transformer RMS does NOT predict loss spikes"),
        f("appc-variance", "quantization noise variance grows ∝ inner dim k (eq. 14)"),
    ]
}

/// The native training scenarios (run via `switchback train`, no PJRT).
pub fn native_scenarios() -> Vec<ExpEntry> {
    let n = |name, desc| ExpEntry { name, desc, needs_pjrt: false };
    vec![
        n(
            "train-smoke",
            "short native CLIP run per precision kind; asserts the loss decreases",
        ),
        n(
            "train-spikes",
            "shift-schedule spike scenario: AdamW vs StableAdamW spike counts \
             (SwitchBack vs Standard kinds), BENCH_train.json",
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_across_both_paths() {
        let mut names: Vec<&str> = figure_experiments()
            .iter()
            .chain(native_scenarios().iter())
            .map(|e| e.name)
            .collect();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate experiment names");
    }

    #[test]
    fn gating_is_recorded() {
        assert!(figure_experiments().iter().all(|e| e.needs_pjrt));
        assert!(native_scenarios().iter().all(|e| !e.needs_pjrt));
    }
}
