//! Pieces shared by the two training paths.
//!
//! The PJRT artifact trainer (`coordinator::trainer`, feature `pjrt`) and
//! the native trainer (`crate::train`) drive different forward/backward
//! engines but identical *training policy*: the same optimizer zoo, the
//! same warmup+cosine schedule, and the same deterministic spike-trigger
//! shift schedule.  That policy lives here, un-gated, so neither path
//! duplicates it.

use crate::config::{OptimizerKind, TrainHyper};
use crate::data::Shift;
use crate::optim::{AdamW, AdamWConfig, Lion, LionConfig, Optimizer, ParamMeta};
use crate::telemetry::SpikeConfig;

/// Build the configured optimizer over `sizes`-shaped flat tensors.
///
/// This is the single place the `OptimizerKind` → implementation mapping
/// exists (both trainers call it).
pub fn build_optimizer(
    h: &TrainHyper,
    metas: &[ParamMeta],
    sizes: &[usize],
) -> Box<dyn Optimizer> {
    match h.optimizer {
        OptimizerKind::Adamw | OptimizerKind::StableAdamw => {
            let acfg = AdamWConfig {
                beta1: h.beta1,
                beta2: h.beta2,
                eps: 1e-6,
                weight_decay: h.weight_decay,
                update_clipping: h.optimizer == OptimizerKind::StableAdamw,
                beta2_schedule_lambda: h.beta2_lambda,
            };
            Box::new(AdamW::new(acfg, metas, sizes))
        }
        OptimizerKind::Lion => Box::new(Lion::new(
            LionConfig {
                beta1: h.beta1,
                beta2: h.beta2,
                weight_decay: h.weight_decay,
            },
            metas,
            sizes,
        )),
    }
}

/// The stuck-in-the-past trigger schedule: abrupt input-gain changes late
/// in the run (post-warmup), when β₂ history is long and LR is still high.
pub fn spike_shifts(steps: u64) -> Vec<Shift> {
    let s1 = steps * 55 / 100;
    let s2 = steps * 70 / 100;
    let s3 = steps * 85 / 100;
    vec![
        Shift { at_step: s1, image_gain: 6.0, remap_concepts: false },
        Shift { at_step: s2, image_gain: 1.0 / 6.0, remap_concepts: true },
        Shift { at_step: s3, image_gain: 8.0, remap_concepts: false },
    ]
}

/// Spike-detection config scaled to a run length (paper burn-in is 1000 of
/// 20k iterations; ours keeps the same 1/8 proportion, floored at 20).
pub fn spike_cfg(steps: u64) -> SpikeConfig {
    SpikeConfig { burn_in: (steps / 8).max(20), ..Default::default() }
}

/// Mean loss over the last 10% of steps (min 1), counting only finite
/// values — the robust curve endpoint both trainers report.  A NaN step
/// must not bias the mean low by inflating the divisor; NaN when the
/// trace is empty or the whole tail is nonfinite.
pub fn tail_mean_loss(losses: &[f32]) -> f32 {
    if losses.is_empty() {
        return f32::NAN;
    }
    let tail_n = (losses.len() / 10).max(1);
    let finite: Vec<f32> = losses[losses.len() - tail_n..]
        .iter()
        .copied()
        .filter(|v| v.is_finite())
        .collect();
    if finite.is_empty() {
        f32::NAN
    } else {
        finite.iter().sum::<f32>() / finite.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metas(n: usize) -> Vec<ParamMeta> {
        (0..n).map(|i| ParamMeta::weight(&format!("p{i}"))).collect()
    }

    #[test]
    fn builds_every_kind() {
        for (kind, name) in [
            (OptimizerKind::Adamw, "adamw"),
            (OptimizerKind::StableAdamw, "stable_adamw"),
            (OptimizerKind::Lion, "lion"),
        ] {
            let h = TrainHyper { optimizer: kind, ..TrainHyper::preset(10) };
            let opt = build_optimizer(&h, &metas(2), &[3, 4]);
            assert_eq!(opt.name(), name);
        }
    }

    #[test]
    fn shift_schedule_is_post_warmup_and_ordered() {
        let shifts = spike_shifts(200);
        assert_eq!(shifts.len(), 3);
        assert!(shifts[0].at_step > 200 / 4, "shifts must land after warmup");
        assert!(shifts.windows(2).all(|w| w[0].at_step < w[1].at_step));
        assert!(shifts.iter().any(|s| s.remap_concepts));
    }

    #[test]
    fn spike_cfg_scales_burn_in() {
        assert_eq!(spike_cfg(50).burn_in, 20);
        assert_eq!(spike_cfg(400).burn_in, 50);
    }

    #[test]
    fn tail_mean_ignores_nonfinite_without_biasing() {
        assert!(tail_mean_loss(&[]).is_nan());
        // 20 steps → tail is the last 2; a NaN in the tail must not halve
        // the mean (divide by finite count, not tail length)
        let mut losses = vec![5.0f32; 18];
        losses.push(f32::NAN);
        losses.push(2.0);
        assert_eq!(tail_mean_loss(&losses), 2.0);
        // all-nonfinite tail → NaN, short traces use the last step
        losses[19] = f32::INFINITY;
        assert!(tail_mean_loss(&losses).is_nan());
        assert_eq!(tail_mean_loss(&[3.0]), 3.0);
    }
}
