//! The L3 coordinator: training-loop orchestration + experiment sweeps.
//!
//! * [`trainer`] — the full training loop over an AOT artifact: data →
//!   PJRT step → (optional loss-scaler) → (optional grad clip) →
//!   optimizer → telemetry.
//! * [`eval`] — zero-shot-style evaluation (classify eval images against
//!   each concept's canonical caption embedding — the ImageNet-80-prompt
//!   analogue).
//! * [`experiments`] — the registry mapping every paper figure to a set of
//!   runs and a printed summary (DESIGN.md experiment index).

pub mod eval;
pub mod experiments;
pub mod trainer;

pub use trainer::{RunResult, Trainer};
