//! The L3 coordinator: training-loop orchestration + experiment sweeps.
//!
//! * [`common`] — training policy shared by *both* training paths (PJRT
//!   artifact runs and the native `crate::train` subsystem): optimizer
//!   construction from [`crate::config::TrainHyper`], the deterministic
//!   spike-trigger shift schedule, and run-scaled spike detection.
//! * [`registry`] — the experiment/scenario registry (un-gated listing).
//! * [`eval`] — zero-shot-style evaluation; the nearest-class core is
//!   un-gated and shared with the native path.
//! * `trainer` (feature `pjrt`) — the full training loop over an AOT
//!   artifact: data → PJRT step → (optional loss-scaler) → (optional grad
//!   clip) → optimizer → telemetry.
//! * `experiments` (feature `pjrt`) — the runners mapping every paper
//!   figure to a set of runs and a printed summary (DESIGN.md experiment
//!   index).

pub mod common;
pub mod eval;
pub mod registry;

#[cfg(feature = "pjrt")]
pub mod experiments;
#[cfg(feature = "pjrt")]
pub mod trainer;

#[cfg(feature = "pjrt")]
pub use trainer::{RunResult, Trainer};
