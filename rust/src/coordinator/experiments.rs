//! Experiment registry: every figure in the paper, regenerated.
//!
//! Each `fig*` function builds its run matrix, executes it through the
//! full stack (artifact → PJRT → rust optimizer → telemetry), writes JSONL
//! logs under `results/<exp>/`, and prints the figure-shaped summary the
//! paper reports (who wins, by how much, where the crossovers are).  See
//! DESIGN.md's experiment index for the exp ↔ figure mapping and
//! EXPERIMENTS.md for recorded paper-vs-measured outcomes.

use crate::config::{OptimizerKind, ScalerKind, TrainConfig};
use crate::coordinator::common::{spike_cfg, spike_shifts};
use crate::coordinator::trainer::{RunResult, Trainer};
use crate::quant;
use crate::runtime::Runtime;
use crate::telemetry::{detect_loss_spikes, detect_rms_spikes, lead_lag_from_events};
use crate::tensor::Rng;
use anyhow::{bail, Result};

/// Shared context for all experiments.
pub struct ExpCtx {
    pub runtime: Runtime,
    /// global step-count override (0 = per-experiment default)
    pub steps: u64,
    pub out_dir: String,
    pub verbose: bool,
    /// compiled-artifact cache: sweeps reuse executables across runs
    /// (compilation dominates short-run wall time — EXPERIMENTS.md §Perf)
    cache: std::cell::RefCell<
        std::collections::HashMap<String, std::rc::Rc<crate::runtime::Artifact>>,
    >,
}

impl ExpCtx {
    pub fn new(runtime: Runtime, steps: u64, out_dir: String, verbose: bool) -> Self {
        Self { runtime, steps, out_dir, verbose, cache: Default::default() }
    }

    fn steps_or(&self, default: u64) -> u64 {
        if self.steps > 0 {
            self.steps
        } else {
            default
        }
    }

    fn artifact(&self, dir: &str, name: &str) -> Result<std::rc::Rc<crate::runtime::Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let a = std::rc::Rc::new(self.runtime.load(dir, name)?);
        self.cache.borrow_mut().insert(name.to_string(), a.clone());
        Ok(a)
    }

    fn run(&self, exp: &str, tag: &str, mut cfg: TrainConfig) -> Result<RunResult> {
        cfg.metrics_path =
            Some(format!("{}/{}/{}.jsonl", self.out_dir, exp, tag));
        let artifact = self.artifact(&cfg.artifact_dir, &cfg.artifact)?;
        let mut trainer = Trainer::with_artifact(&self.runtime, artifact, cfg);
        let res = trainer.run(self.verbose)?;
        println!(
            "  [{tag}] tail-loss {:7.4}  acc {}  {}  ({:.1} steps/s)",
            res.tail_loss,
            res.zero_shot_acc
                .map(|a| format!("{:5.1}%", 100.0 * a))
                .unwrap_or_else(|| "  n/a".into()),
            if res.diverged { "DIVERGED" } else { "ok" },
            res.steps_per_sec,
        );
        Ok(res)
    }
}

fn count_spikes(res: &RunResult, steps: u64) -> usize {
    detect_loss_spikes(&res.sink.loss_trace(), &spike_cfg(steps)).len()
}

/// The figure-experiment listing (delegates to the shared registry).
pub fn list() -> Vec<(&'static str, &'static str)> {
    crate::coordinator::registry::figure_experiments()
        .into_iter()
        .map(|e| (e.name, e.desc))
        .collect()
}

pub fn run_experiment(ctx: &ExpCtx, name: &str) -> Result<()> {
    match name {
        "fig1-int8" => fig1(ctx, "int8"),
        "fig1-fp8" => fig1(ctx, "fp8"),
        "fig2" => fig2(ctx),
        "fig5-divergence" => fig5_divergence(ctx),
        "fig5-magnitude" => fig5_magnitude(ctx),
        "fig6" => fig678(ctx, "fig6", Axis::ModelSize),
        "fig7" => fig678(ctx, "fig7", Axis::BatchSize),
        "fig8" => fig678(ctx, "fig8", Axis::LearningRate),
        "fig9" => fig9(ctx),
        "fig10" => fig10(ctx),
        "fig11" => fig11(ctx),
        "fig14" => fig14(ctx),
        "fig15" => fig15(ctx),
        "fig16" => fig16_like(ctx, "fig16", "small", false),
        "fig17" => fig16_like(ctx, "fig17", "tiny", false),
        "fig21" => fig16_like(ctx, "fig21", "small", true),
        "appc-variance" => appc_variance(),
        other => bail!("unknown experiment {other:?} — see `switchback exp --list`"),
    }
}

// ---------------------------------------------------------------------
// Fig 1 + 2: accuracy vs scale for the precision variants
// ---------------------------------------------------------------------

fn fig1(ctx: &ExpCtx, mode: &str) -> Result<()> {
    let steps = ctx.steps_or(300);
    let variants: &[&str] = if mode == "int8" {
        &["highprec", "switchback_int8", "llmint8"]
    } else {
        &["highprec", "fp8_tensorwise", "switchback_fp8"]
    };
    let sizes = ["micro", "tiny", "small"];
    println!("== Fig 1 ({mode}): zero-shot accuracy vs model scale ==");
    println!("   (paper: SwitchBack within 0.1pp of bf16 at ViT-H; LLM.int8 −5.9pp; tensor-wise fp8 diverges at scale)");
    let exp = format!("fig1-{mode}");
    let mut rows = vec![];
    for size in sizes {
        for variant in variants {
            let artifact = format!("{variant}_{size}_b32");
            let cfg = TrainConfig::preset(&artifact, steps);
            let res = ctx.run(&exp, &artifact, cfg)?;
            rows.push((size, *variant, res.zero_shot_acc.unwrap_or(f32::NAN),
                       res.tail_loss, res.diverged));
        }
    }
    println!("\n  size     variant             acc      tail-loss");
    for (size, variant, acc, loss, div) in &rows {
        println!(
            "  {size:<8} {variant:<18} {:6.1}%   {loss:8.4} {}",
            100.0 * acc,
            if *div { "DIVERGED" } else { "" }
        );
    }
    // headline deltas vs highprec per size
    println!("\n  Δacc vs highprec (paper Fig 1 shape):");
    for size in sizes {
        let base = rows.iter().find(|r| r.0 == size && r.1 == "highprec").unwrap().2;
        for (s, v, acc, _, _) in &rows {
            if *s == size && *v != "highprec" {
                println!("  {size:<8} {v:<18} {:+6.1}pp", 100.0 * (acc - base));
            }
        }
    }
    Ok(())
}

fn fig2(ctx: &ExpCtx) -> Result<()> {
    println!("== Fig 2: loss curves for the Fig 1 runs ==");
    let mut any = false;
    for mode in ["int8", "fp8"] {
        let dir = format!("{}/fig1-{mode}", ctx.out_dir);
        let Ok(entries) = std::fs::read_dir(&dir) else { continue };
        for e in entries.flatten() {
            let path = e.path();
            if path.extension().map(|x| x != "jsonl").unwrap_or(true) {
                continue;
            }
            any = true;
            let text = std::fs::read_to_string(&path)?;
            let losses: Vec<f32> = text
                .lines()
                .filter_map(crate::telemetry::StepRecord::from_json)
                .map(|r| r.loss)
                .collect();
            let name = path.file_stem().unwrap().to_string_lossy().to_string();
            print!("  {name:<32}");
            let n = losses.len().max(1);
            for i in 0..10 {
                let idx = (i * n / 10).min(n - 1);
                print!(" {:7.3}", losses[idx]);
            }
            println!();
        }
    }
    if !any {
        bail!("no fig1 logs found — run `switchback exp fig1-int8` / `fig1-fp8` first");
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Fig 5: fp8 divergence rescue + feature magnitudes
// ---------------------------------------------------------------------

fn fig5_divergence(ctx: &ExpCtx) -> Result<()> {
    let steps = ctx.steps_or(300);
    println!("== Fig 5 (left): fp8 tensor-wise rescue attempts (paper's ViT-L slot = `small`) ==");
    let runs: Vec<(&str, TrainConfig)> = vec![
        ("bf16-baseline", TrainConfig::preset("highprec_small_b32", steps)),
        ("fp8-tensorwise", TrainConfig::preset("fp8_tensorwise_small_b32", steps)),
        ("fp8+gradclip1", {
            let mut c = TrainConfig::preset("fp8_tensorwise_small_b32", steps);
            c.grad_clip = Some(1.0);
            c
        }),
        ("fp8+kq-norm", TrainConfig::preset("fp8_tensorwise_small_kqn_b32", steps)),
        ("fp8+layerscale0", TrainConfig::preset("fp8_tensorwise_small_ls_b32", steps)),
    ];
    let mut results = vec![];
    for (tag, cfg) in runs {
        let res = ctx.run("fig5-divergence", tag, cfg)?;
        results.push((tag, res));
    }
    println!("\n  run               tail-loss   acc    status   (paper: only layerscale0 trains)");
    for (tag, res) in &results {
        println!(
            "  {tag:<17} {:9.4}  {:5.1}%  {}",
            res.tail_loss,
            100.0 * res.zero_shot_acc.unwrap_or(f32::NAN),
            if res.diverged { "DIVERGED" } else { "ok" },
        );
    }
    Ok(())
}

fn fig5_magnitude(ctx: &ExpCtx) -> Result<()> {
    let steps = ctx.steps_or(300);
    println!("== Fig 5 (right): per-block E[|x_k|], init vs end, ± zero-init layer-scale ==");
    for (tag, artifact) in [
        ("no-layerscale", "highprec_small_b32"),
        ("layerscale0", "highprec_small_ls_b32"),
    ] {
        let res = ctx.run("fig5-magnitude", tag, TrainConfig::preset(artifact, steps))?;
        let fmt = |v: &[f32]| {
            v.iter().map(|x| format!("{x:6.2}")).collect::<Vec<_>>().join(" ")
        };
        println!("  {tag:<14} init: {}", fmt(&res.mags_first));
        println!("  {tag:<14} end : {}", fmt(&res.mags_last));
    }
    println!("  (paper: without the intervention, magnitudes grow with depth; layer-scale keeps them flat)");
    Ok(())
}

// ---------------------------------------------------------------------
// Fig 6/7/8: spike counts vs size / batch / lr, ablating β2
// ---------------------------------------------------------------------

enum Axis {
    ModelSize,
    BatchSize,
    LearningRate,
}

fn fig678(ctx: &ExpCtx, exp: &str, axis: Axis) -> Result<()> {
    let steps = ctx.steps_or(240);
    let betas = [0.999f32, 0.99, 0.95, 0.9];
    let cells: Vec<(String, String, f32)> = match axis {
        Axis::ModelSize => ["micro", "tiny", "small"]
            .iter()
            .map(|s| (s.to_string(), format!("highprec_{s}_b32"), 2e-3))
            .collect(),
        Axis::BatchSize => [8usize, 32, 128, 512]
            .iter()
            .map(|b| (format!("batch{b}"), format!("highprec_micro_b{b}"), 2e-3))
            .collect(),
        Axis::LearningRate => [1e-3f32, 2e-3, 4e-3, 8e-3]
            .iter()
            .map(|lr| (format!("lr{lr:.0e}"), "highprec_tiny_b32".to_string(), *lr))
            .collect(),
    };
    let what = match axis {
        Axis::ModelSize => "model size",
        Axis::BatchSize => "batch size",
        Axis::LearningRate => "learning rate",
    };
    println!("== {exp}: loss spikes vs {what} × β2 (AdamW, shift schedule on) ==");
    println!("  (paper: spikes increase along the axis; lowering β2 removes them; too low slows training)");
    let mut table = vec![];
    for (label, artifact, lr) in &cells {
        for beta2 in betas {
            let mut cfg = TrainConfig::preset(artifact, steps)
                .with_optimizer(OptimizerKind::Adamw, beta2);
            cfg.lr = *lr;
            cfg.shifts = spike_shifts(steps);
            let tag = format!("{label}_b2-{beta2}");
            let res = ctx.run(exp, &tag, cfg)?;
            let spikes = count_spikes(&res, steps);
            table.push((label.clone(), beta2, spikes, res.tail_loss,
                        res.zero_shot_acc.unwrap_or(f32::NAN)));
        }
    }
    println!("\n  cell        β2      spikes  tail-loss    acc");
    for (label, b2, spikes, loss, acc) in &table {
        println!(
            "  {label:<11} {b2:<6}  {spikes:>4}   {loss:9.4}  {:5.1}%",
            100.0 * acc
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Fig 9 / 16 / 17 / 21: RMS spikes precede loss spikes
// ---------------------------------------------------------------------

fn fig9(ctx: &ExpCtx) -> Result<()> {
    let steps = ctx.steps_or(300);
    println!("== Fig 9: RMS_t (patch embedding) spikes precede loss spikes ==");
    let mut cfg = TrainConfig::preset("highprec_tiny_b32", steps)
        .with_optimizer(OptimizerKind::Adamw, 0.999);
    cfg.shifts = spike_shifts(steps);
    let res = ctx.run("fig9", "adamw_b2-0.999", cfg)?;
    let sc = spike_cfg(steps);
    let loss = res.sink.loss_trace();
    let rms = res.sink.rms_trace(&res.probe_names.0);
    let report = crate::telemetry::lead_lag_analysis(&loss, &rms, &sc);
    println!("  {}", report.summary());
    for &t in &report.loss_spikes {
        let t = t as usize;
        let lo = t.saturating_sub(10);
        println!("  around loss spike @ {t}:");
        print!("    loss:");
        for i in lo..(t + 3).min(loss.len()) {
            print!(" {:6.3}", loss[i]);
        }
        print!("\n    RMS :");
        for i in lo..(t + 3).min(rms.len()) {
            print!(" {:6.2}", rms[i]);
        }
        println!();
    }
    // the paper's contrast: lower β2 keeps RMS near 1
    let mut cfg2 = TrainConfig::preset("highprec_tiny_b32", steps)
        .with_optimizer(OptimizerKind::Adamw, 0.95);
    cfg2.shifts = spike_shifts(steps);
    let res2 = ctx.run("fig9", "adamw_b2-0.95", cfg2)?;
    let rms2 = res2.sink.rms_trace(&res2.probe_names.0);
    let max2 = rms2.iter().fold(0.0f32, |m, &v| m.max(v));
    println!("  β2=0.95: max RMS_t = {max2:.2} (paper: stays near 1 for lower β2)");
    Ok(())
}

fn fig16_like(ctx: &ExpCtx, exp: &str, size: &str, use_mid_control: bool) -> Result<()> {
    let steps = ctx.steps_or(260);
    let which = if use_mid_control {
        "mid-transformer control tensor (Fig 21)"
    } else {
        "patch embedding"
    };
    println!("== {exp}: pooled lead-lag statistics over β2 sweeps — probe: {which} ==");
    let betas = [0.999f32, 0.998, 0.995];
    let mut all_loss_spikes = vec![];
    let mut all_rms_spikes = vec![];
    let mut total_len = 0u64;
    let sc = spike_cfg(steps);
    for (i, beta2) in betas.iter().enumerate() {
        let mut cfg = TrainConfig::preset(&format!("highprec_{size}_b32"), steps)
            .with_optimizer(OptimizerKind::Adamw, *beta2);
        cfg.shifts = spike_shifts(steps);
        cfg.seed = i as u64;
        cfg.reinit = i != 0;
        let res = ctx.run(exp, &format!("b2-{beta2}"), cfg)?;
        let loss = res.sink.loss_trace();
        let probe = if use_mid_control { &res.probe_names.1 } else { &res.probe_names.0 };
        let rms = res.sink.rms_trace(probe);
        // pool events with a per-run offset so windows never straddle runs
        let off = total_len;
        all_loss_spikes.extend(detect_loss_spikes(&loss, &sc).iter().map(|t| t + off));
        all_rms_spikes.extend(detect_rms_spikes(&rms, &sc).iter().map(|t| t + off));
        total_len += loss.len() as u64 + 100;
    }
    let report = lead_lag_from_events(&all_loss_spikes, &all_rms_spikes, total_len);
    println!("  pooled: {}", report.summary());
    if use_mid_control {
        println!("  (paper Fig 21: for a mid-transformer tensor, NONE of the loss spikes follow RMS spikes)");
    } else {
        println!("  (paper Fig 16/17: 14/15 resp. 13/15 loss spikes follow an RMS spike by 1–8 iters, ~1% by chance)");
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Fig 10: StableAdamW vs gradient clipping
// ---------------------------------------------------------------------

fn fig10(ctx: &ExpCtx) -> Result<()> {
    let steps = ctx.steps_or(300);
    println!("== Fig 10: update clipping (StableAdamW) vs gradient clipping vs β2 ==");
    let mut rows = vec![];
    for beta2 in [0.999f32, 0.99, 0.95] {
        for (tag, opt, clip) in [
            ("adamw", OptimizerKind::Adamw, None),
            ("adamw+gradclip1", OptimizerKind::Adamw, Some(1.0)),
            ("stable_adamw", OptimizerKind::StableAdamw, None),
        ] {
            let mut cfg = TrainConfig::preset("highprec_small_b32", steps)
                .with_optimizer(opt, beta2);
            cfg.grad_clip = clip;
            cfg.shifts = spike_shifts(steps);
            let label = format!("{tag}_b2-{beta2}");
            let res = ctx.run("fig10", &label, cfg)?;
            rows.push((tag, beta2, count_spikes(&res, steps), res.tail_loss,
                       res.zero_shot_acc.unwrap_or(f32::NAN)));
        }
    }
    println!("\n  optimizer         β2      spikes  tail-loss    acc   (paper: StableAdamW removes spikes AND beats gradclip on acc; β2=0.99 best with clipping)");
    for (tag, b2, spikes, loss, acc) in &rows {
        println!(
            "  {tag:<17} {b2:<6}  {spikes:>4}   {loss:9.4}  {:5.1}%",
            100.0 * acc
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Fig 11: spikes ↔ activations/gradients ↔ loss scalar
// ---------------------------------------------------------------------

fn fig11(ctx: &ExpCtx) -> Result<()> {
    let steps = ctx.steps_or(300);
    println!("== Fig 11: loss spikes co-occur with activation/gradient spikes and scaler drops ==");
    let mut cfg = TrainConfig::preset("highprec_tiny_b32", steps)
        .with_optimizer(OptimizerKind::Adamw, 0.999);
    cfg.shifts = spike_shifts(steps);
    cfg.scaler = ScalerKind::DynamicGlobal;
    let res = ctx.run("fig11", "dynamic_scaler", cfg)?;
    let sc = spike_cfg(steps);
    let loss = res.sink.loss_trace();
    let spikes = detect_loss_spikes(&loss, &sc);
    println!("  loss spikes at: {spikes:?}");
    println!("  loss-scale drops: {}", res.sink.scale_drops());
    let pe = &res.probe_names.0;
    for &t in spikes.iter().take(4) {
        let t = t as usize;
        let lo = t.saturating_sub(3);
        let hi = (t + 4).min(res.sink.records.len());
        println!("  around step {t} (probe {pe}):");
        for r in &res.sink.records[lo..hi] {
            let probe = r.grad_probes.get(pe);
            println!(
                "    step {:>4} loss {:7.3} |g| {:9.3} grad-max {:9.3} feat-mag {:6.3} scale {:?} skipped {}",
                r.step,
                r.loss,
                r.grad_norm,
                probe.map(|p| p.max_abs).unwrap_or(0.0),
                r.feature_mags.first().copied().unwrap_or(0.0),
                r.loss_scale,
                r.skipped_step,
            );
        }
    }
    // contrast with the paper's fixed tensor-level scaler
    let mut cfg2 = TrainConfig::preset("highprec_tiny_b32", steps)
        .with_optimizer(OptimizerKind::Adamw, 0.999);
    cfg2.shifts = spike_shifts(steps);
    cfg2.scaler = ScalerKind::FixedTensor;
    let res2 = ctx.run("fig11", "fixed_tensor_scaler", cfg2)?;
    let skipped: usize = res2.sink.records.iter().map(|r| r.skipped_tensors).sum();
    let full_skips: usize = res2.sink.records.iter().filter(|r| r.skipped_step).count();
    println!(
        "  fixed tensor-level scaler: {skipped} tensor-updates skipped, {full_skips} whole-step skips (paper: skips localize to the patch embedding)"
    );
    Ok(())
}

// ---------------------------------------------------------------------
// Fig 14: magnitudes through training
// ---------------------------------------------------------------------

fn fig14(ctx: &ExpCtx) -> Result<()> {
    let steps = ctx.steps_or(300);
    println!("== Fig 14 (+App B.2): gradient/activation mean & max through training ==");
    for (tag, artifact) in [
        ("small", "highprec_small_b32"),
        ("small+layerscale", "highprec_small_ls_b32"),
    ] {
        let res = ctx.run("fig14", tag, TrainConfig::preset(artifact, steps))?;
        let pe = &res.probe_names.0;
        println!("  {tag}: step → [grad mean|max of {pe}] [block-0 feature mag]");
        let n = res.sink.records.len();
        for i in (0..n).step_by((n / 8).max(1)) {
            let r = &res.sink.records[i];
            if let Some(p) = r.grad_probes.get(pe) {
                println!(
                    "    {:>5}  {:9.5} | {:9.4}   feat {:6.3}",
                    r.step,
                    p.mean_abs,
                    p.max_abs,
                    r.feature_mags.first().copied().unwrap_or(0.0)
                );
            }
        }
    }
    println!("  (paper App B.2: the absmax evolves smoothly — which is what makes tensor-wise fp8 a good proxy for scaler-free training)");
    Ok(())
}

// ---------------------------------------------------------------------
// Fig 15: β2 warmup schedule
// ---------------------------------------------------------------------

fn fig15(ctx: &ExpCtx) -> Result<()> {
    let steps = ctx.steps_or(300);
    println!("== Fig 15: β2 schedule 1−t^−λ (AdaFactor/PaLM style) vs constant β2 ==");
    let mut rows = vec![];
    for lambda in [0.45f32, 0.5, 0.65] {
        let mut cfg = TrainConfig::preset("highprec_tiny_b32", steps)
            .with_optimizer(OptimizerKind::StableAdamw, 0.999);
        cfg.beta2_lambda = Some(lambda);
        let final_b2 = 1.0 - (steps as f32).powf(-lambda);
        let res = ctx.run("fig15", &format!("lambda-{lambda}"), cfg)?;
        rows.push((format!("λ={lambda} (β2_final={final_b2:.4})"),
                   res.zero_shot_acc.unwrap_or(f32::NAN), res.tail_loss));
    }
    for beta2 in [0.99f32, 0.999] {
        let cfg = TrainConfig::preset("highprec_tiny_b32", steps)
            .with_optimizer(OptimizerKind::StableAdamw, beta2);
        let res = ctx.run("fig15", &format!("const-{beta2}"), cfg)?;
        rows.push((format!("const β2={beta2}"),
                   res.zero_shot_acc.unwrap_or(f32::NAN), res.tail_loss));
    }
    println!("\n  schedule                        acc     tail-loss   (paper: the schedule does not improve accuracy)");
    for (tag, acc, loss) in rows {
        println!("  {tag:<30} {:5.1}%  {loss:9.4}", 100.0 * acc);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Appendix C: quantization noise variance ∝ k (pure rust, no artifacts)
// ---------------------------------------------------------------------

fn appc_variance() -> Result<()> {
    println!("== Appendix C: Var(⟨û,v̂⟩ − ⟨u,v⟩) grows ∝ k (eq. 14) ==");
    let trials = 400;
    let mut rng = Rng::seed(2023);
    println!("  k        noise-var      noise-var/k   (constant ⇒ linear growth)");
    let mut ratios = vec![];
    for k in [128usize, 512, 2048, 8192, 32768] {
        let mut var = 0.0f64;
        for _ in 0..trials {
            let u = crate::tensor::Matrix::randn(1, k, 1.0, &mut rng);
            let v = crate::tensor::Matrix::randn(1, k, 1.0, &mut rng);
            let exact: f64 = u
                .data
                .iter()
                .zip(&v.data)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            let uq = quant::rowwise_quant(&u);
            let vq = quant::rowwise_quant(&v);
            let qdot: f64 = uq
                .codes
                .data
                .iter()
                .zip(&vq.codes.data)
                .map(|(a, b)| (*a as i32 * *b as i32) as f64)
                .sum::<f64>()
                * (uq.state[0] as f64 / 127.0)
                * (vq.state[0] as f64 / 127.0);
            var += (qdot - exact).powi(2);
        }
        var /= trials as f64;
        println!("  {k:<8} {var:12.4}   {:12.6}", var / k as f64);
        ratios.push(var / k as f64);
    }
    println!("  (paper: this is why the wgrad — inner dim ≈ 32768 in their CLIP runs — must stay high-precision)");
    Ok(())
}
