//! Zero-shot-style evaluation — the ImageNet-with-80-prompts analogue.
//!
//! Each concept's canonical caption plays the role of a class prompt: we
//! embed every canonical caption once, embed held-out images, and classify
//! each image to the nearest caption embedding (cosine).  Accuracy over
//! concepts is the headline metric of Fig 1 / Fig 10.
//!
//! The classification core ([`nearest_class_accuracy`]) is embedding-space
//! only and un-gated: the PJRT path feeds it artifact-encoded embeddings
//! (`zero_shot_accuracy`, feature `pjrt`), the native path feeds it
//! `train::ClipTrainModel` embeddings.

/// Cosine-similarity argmax classification over flat embedding buffers.
///
/// `img_embs` is `[n_eval, edim]` row-major, `class_embs` is
/// `[n_classes, edim]` row-major, `labels[i]` is the true class of eval
/// row `i`.  Embeddings are assumed L2-normalized (dot = cosine).
pub fn nearest_class_accuracy(
    img_embs: &[f32],
    class_embs: &[f32],
    edim: usize,
    labels: &[usize],
) -> f32 {
    assert!(edim > 0, "embedding dim must be positive");
    assert_eq!(img_embs.len(), labels.len() * edim, "eval embedding shape");
    assert_eq!(class_embs.len() % edim, 0, "class embedding shape");
    let n_classes = class_embs.len() / edim;
    if labels.is_empty() || n_classes == 0 {
        return 0.0;
    }
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let emb = &img_embs[i * edim..(i + 1) * edim];
        let mut best = 0usize;
        let mut best_sim = f32::NEG_INFINITY;
        for k in 0..n_classes {
            let ce = &class_embs[k * edim..(k + 1) * edim];
            let sim: f32 = emb.iter().zip(ce).map(|(a, b)| a * b).sum();
            if sim > best_sim {
                best_sim = sim;
                best = k;
            }
        }
        if best == label {
            correct += 1;
        }
    }
    correct as f32 / labels.len() as f32
}

/// PJRT-path zero-shot accuracy: encode canonical captions + eval images
/// through the AOT artifact, then classify with the shared core.
#[cfg(feature = "pjrt")]
pub fn zero_shot_accuracy(
    artifact: &crate::runtime::Artifact,
    params: &[Vec<f32>],
    data: &crate::data::SyntheticClip,
    per_concept: usize,
) -> anyhow::Result<f32> {
    let m = &artifact.manifest;
    let batch = m.batch;
    let edim = m.config.embed_dim;
    let n_concepts = data.config().n_concepts;

    // 1) class-prompt embeddings: encode canonical captions (batched,
    //    padded; images input is a dummy for the text side of encode).
    let img_len = m.config.patches * m.config.patch_dim;
    let mut class_embs = vec![0.0f32; n_concepts * edim];
    let dummy_images = vec![0.0f32; batch * img_len];
    let mut c = 0;
    while c < n_concepts {
        let take = batch.min(n_concepts - c);
        let mut tokens = Vec::with_capacity(batch * m.config.seq);
        for i in 0..batch {
            let concept = if i < take { c + i } else { 0 };
            tokens.extend(data.canonical_caption(concept));
        }
        let (_, txt) = artifact.encode(params, &dummy_images, &tokens)?;
        class_embs[c * edim..(c + take) * edim].copy_from_slice(&txt[..take * edim]);
        c += take;
    }

    // 2) eval images, batched + padded, gathered into one flat buffer.
    let eval = data.eval_set(per_concept);
    let n_eval = eval.concepts.len();
    let mut eval_embs = vec![0.0f32; n_eval * edim];
    let mut idx = 0;
    while idx < n_eval {
        let take = batch.min(n_eval - idx);
        let mut images = vec![0.0f32; batch * img_len];
        let mut tokens = vec![0i32; batch * m.config.seq];
        for i in 0..take {
            images[i * img_len..(i + 1) * img_len].copy_from_slice(
                &eval.images[(idx + i) * img_len..(idx + i + 1) * img_len],
            );
            tokens[i * m.config.seq..(i + 1) * m.config.seq].copy_from_slice(
                &eval.tokens[(idx + i) * m.config.seq..(idx + i + 1) * m.config.seq],
            );
        }
        let (img_embs, _) = artifact.encode(params, &images, &tokens)?;
        eval_embs[idx * edim..(idx + take) * edim].copy_from_slice(&img_embs[..take * edim]);
        idx += take;
    }

    Ok(nearest_class_accuracy(&eval_embs, &class_embs, edim, &eval.concepts))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifies_by_cosine_argmax() {
        // 3 orthogonal classes in 3-d; eval rows slightly noisy copies
        let class_embs = vec![
            1.0, 0.0, 0.0, //
            0.0, 1.0, 0.0, //
            0.0, 0.0, 1.0,
        ];
        let img_embs = vec![
            0.9, 0.1, 0.0, // class 0
            0.1, 0.9, 0.1, // class 1
            0.0, 0.2, 0.9, // class 2
            0.9, 0.0, 0.1, // class 0 again, mislabeled as 1 below
        ];
        let acc = nearest_class_accuracy(&img_embs, &class_embs, 3, &[0, 1, 2, 1]);
        assert!((acc - 0.75).abs() < 1e-6, "3 of 4 correct, got {acc}");
    }

    #[test]
    fn empty_eval_is_zero() {
        assert_eq!(nearest_class_accuracy(&[], &[1.0, 0.0], 2, &[]), 0.0);
    }
}
