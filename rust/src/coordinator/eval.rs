//! Zero-shot-style evaluation — the ImageNet-with-80-prompts analogue.
//!
//! Each concept's canonical caption plays the role of a class prompt: we
//! embed every canonical caption once, embed held-out images, and classify
//! each image to the nearest caption embedding (cosine).  Accuracy over
//! concepts is the headline metric of Fig 1 / Fig 10.

use crate::data::SyntheticClip;
use crate::runtime::Artifact;
use anyhow::Result;

/// Cosine-similarity argmax classification accuracy.
pub fn zero_shot_accuracy(
    artifact: &Artifact,
    params: &[Vec<f32>],
    data: &SyntheticClip,
    per_concept: usize,
) -> Result<f32> {
    let m = &artifact.manifest;
    let batch = m.batch;
    let edim = m.config.embed_dim;
    let n_concepts = data.config().n_concepts;

    // 1) class-prompt embeddings: encode canonical captions (batched,
    //    padded; images input is a dummy for the text side of encode).
    let img_len = m.config.patches * m.config.patch_dim;
    let mut class_embs = vec![0.0f32; n_concepts * edim];
    let dummy_images = vec![0.0f32; batch * img_len];
    let mut c = 0;
    while c < n_concepts {
        let take = batch.min(n_concepts - c);
        let mut tokens = Vec::with_capacity(batch * m.config.seq);
        for i in 0..batch {
            let concept = if i < take { c + i } else { 0 };
            tokens.extend(data.canonical_caption(concept));
        }
        let (_, txt) = artifact.encode(params, &dummy_images, &tokens)?;
        for i in 0..take {
            class_embs[(c + i) * edim..(c + i + 1) * edim]
                .copy_from_slice(&txt[i * edim..(i + 1) * edim]);
        }
        c += take;
    }

    // 2) eval images, batched + padded.
    let eval = data.eval_set(per_concept);
    let n_eval = eval.concepts.len();
    let mut correct = 0usize;
    let mut idx = 0;
    while idx < n_eval {
        let take = batch.min(n_eval - idx);
        let mut images = vec![0.0f32; batch * img_len];
        let mut tokens = vec![0i32; batch * m.config.seq];
        for i in 0..take {
            images[i * img_len..(i + 1) * img_len]
                .copy_from_slice(&eval.images[(idx + i) * img_len..(idx + i + 1) * img_len]);
            tokens[i * m.config.seq..(i + 1) * m.config.seq].copy_from_slice(
                &eval.tokens[(idx + i) * m.config.seq..(idx + i + 1) * m.config.seq],
            );
        }
        let (img_embs, _) = artifact.encode(params, &images, &tokens)?;
        for i in 0..take {
            let emb = &img_embs[i * edim..(i + 1) * edim];
            let mut best = 0usize;
            let mut best_sim = f32::NEG_INFINITY;
            for k in 0..n_concepts {
                let ce = &class_embs[k * edim..(k + 1) * edim];
                let sim: f32 = emb.iter().zip(ce).map(|(a, b)| a * b).sum();
                if sim > best_sim {
                    best_sim = sim;
                    best = k;
                }
            }
            if best == eval.concepts[idx + i] {
                correct += 1;
            }
        }
        idx += take;
    }
    Ok(correct as f32 / n_eval as f32)
}
