//! `train` — the native, PJRT-free end-to-end CLIP training subsystem
//! (DESIGN.md §Train).
//!
//! The paper's headline results are *training* results: SwitchBack int8
//! training matches bf16 within 0.1 pp, and StableAdamW suppresses the
//! loss spikes AdamW suffers under distribution shift.  The PJRT path
//! (`coordinator`, feature `pjrt`) validates those claims through the
//! AOT'd JAX model, but needs a toolchain the offline tier-1 environment
//! lacks.  This module closes the loop natively: the nn layer already has
//! full hand-written backward passes for all four linear variants, so a
//! dual-tower CLIP model built from [`crate::nn::TransformerBlock`]s can
//! train end-to-end on the measured-speed substrate and *show* the
//! loss/spike trajectories instead of only timing kernels.
//!
//! Composition (step loop in [`trainer`]):
//!
//! ```text
//!  data (shift schedule) ──▶ sharded fwd ──▶ global InfoNCE ──▶ sharded
//!  bwd ──▶ ordered grad accumulation ──▶ (grad clip) ──▶ optimizer
//!  (AdamW / StableAdamW / Lion via coordinator::common) ──▶ telemetry
//!  (RMS probes + spike detection + JSONL sink)
//! ```
//!
//! * [`model`] — the trainable dual tower, seeded identically to
//!   `serve::ClipEncoder` (a trained parameter vector drops straight into
//!   the serving engine's world).
//! * [`loss`] — symmetric InfoNCE with a hand-written, finite-difference
//!   tested gradient.
//! * [`trainer`] — the step loop, determinism guarantees, zero-shot eval
//!   through the shared `coordinator::eval` core, and the
//!   `BENCH_train.json` writer.

pub mod loss;
pub mod model;
pub mod trainer;

pub use loss::{clip_contrastive, ContrastiveOut};
pub use model::ClipTrainModel;
pub use trainer::{
    forward_backward, write_bench_train_json, LiveHooks, NativeRunResult,
    NativeTrainConfig, NativeTrainer, StepOutput,
};
