//! The symmetric InfoNCE contrastive loss (CLIP's objective) with a
//! hand-written gradient.
//!
//! Given L2-normalized image embeddings `I [B, e]`, text embeddings
//! `T [B, e]` and a learnable log temperature `log_scale`, the logits are
//! `L = s · I Tᵀ` with `s = min(exp(log_scale), 100)` (CLIP clamps the
//! scale at 100).  The loss averages cross-entropy over rows
//! (image → text retrieval) and over columns (text → image):
//!
//! ```text
//! loss = 1/(2B) Σ_i [ −log softmax_row(L)_ii − log softmax_col(L)_ii ]
//! ```
//!
//! Gradient (derived once, finite-difference tested below):
//!
//! ```text
//! dL_ij   = ((P_ij − δ_ij) + (Q_ij − δ_ij)) / 2B      P = row softmax,
//! d_img   = s · dL  T                                  Q = col softmax
//! d_txt   = s · dLᵀ I
//! d_logs  = s · Σ_ij dL_ij · (I Tᵀ)_ij   (0 when the clamp is active)
//! ```

use crate::gemm::{gemm_f32_nn, gemm_f32_nt};
use crate::tensor::Matrix;

/// CLIP's cap on the learned logit scale.
pub const MAX_LOGIT_SCALE: f32 = 100.0;

/// CLIP's logit-scale init: ln(1/0.07).
pub fn init_log_scale() -> f32 {
    (1.0f32 / 0.07).ln()
}

/// Loss value + gradients w.r.t. both embedding matrices and the log
/// temperature.
pub struct ContrastiveOut {
    pub loss: f32,
    /// in-batch image→text retrieval accuracy (argmax of each row hits
    /// the diagonal) — the cheap per-step learning signal
    pub acc: f32,
    pub d_img: Matrix,
    pub d_txt: Matrix,
    pub d_log_scale: f32,
}

/// Row-wise `logsumexp` of `m` (numerically stable).
fn logsumexp_rows(m: &Matrix) -> Vec<f32> {
    (0..m.rows)
        .map(|r| {
            let row = m.row(r);
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
            mx + sum.ln()
        })
        .collect()
}

/// Symmetric InfoNCE over a square in-batch similarity matrix.
///
/// `img` and `txt` must both be `[B, e]`; rows are expected (not
/// required) to be L2-normalized.  Deterministic: every reduction runs
/// in a fixed sequential order (the GEMMs parallelize only across
/// independent output rows), so the result is identical under any
/// `SWITCHBACK_THREADS` setting.
pub fn clip_contrastive(img: &Matrix, txt: &Matrix, log_scale: f32) -> ContrastiveOut {
    assert_eq!(img.rows, txt.rows, "towers disagree on batch size");
    assert_eq!(img.cols, txt.cols, "towers disagree on embed dim");
    let b = img.rows;
    assert!(b > 0, "empty batch");
    let clamped = log_scale.exp() > MAX_LOGIT_SCALE;
    let s = log_scale.exp().min(MAX_LOGIT_SCALE);

    // cosine similarities and logits
    let sim = gemm_f32_nt(img, txt); // [B, B]
    let mut logits = sim.clone();
    for v in logits.data.iter_mut() {
        *v *= s;
    }
    let lse_rows = logsumexp_rows(&logits);
    let logits_t = logits.transpose();
    let lse_cols = logsumexp_rows(&logits_t);

    // loss + in-batch accuracy off the diagonal
    let mut loss = 0.0f64;
    let mut hits = 0usize;
    for i in 0..b {
        let diag = logits.at(i, i);
        loss += 0.5 * ((lse_rows[i] - diag) as f64 + (lse_cols[i] - diag) as f64);
        let row = logits.row(i);
        let best = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        if row[i] == best {
            hits += 1;
        }
    }
    let loss = (loss / b as f64) as f32;

    // dL = ((P − I) + (Q − I)) / 2B, built row/col softmaxes in place
    let inv2b = 0.5 / b as f32;
    let mut dlogits = Matrix::zeros(b, b);
    for i in 0..b {
        for j in 0..b {
            let p = (logits.at(i, j) - lse_rows[i]).exp(); // row softmax
            let q = (logits.at(i, j) - lse_cols[j]).exp(); // col softmax
            let delta = if i == j { 2.0 } else { 0.0 };
            dlogits.data[i * b + j] = (p + q - delta) * inv2b;
        }
    }

    // chain rule through logits = s · I Tᵀ
    let mut d_img = gemm_f32_nn(&dlogits, txt); // [B, e]
    for v in d_img.data.iter_mut() {
        *v *= s;
    }
    let mut d_txt = gemm_f32_nn(&dlogits.transpose(), img);
    for v in d_txt.data.iter_mut() {
        *v *= s;
    }
    let d_log_scale = if clamped {
        0.0
    } else {
        let ds: f64 = dlogits
            .data
            .iter()
            .zip(&sim.data)
            .map(|(&d, &c)| d as f64 * c as f64)
            .sum();
        (ds * s as f64) as f32
    };

    ContrastiveOut { loss, acc: hits as f32 / b as f32, d_img, d_txt, d_log_scale }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn unit_rows(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut m = Matrix::randn(rows, cols, 1.0, &mut rng);
        for r in 0..rows {
            let row = m.row_mut(r);
            let n: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            for v in row.iter_mut() {
                *v /= n;
            }
        }
        m
    }

    #[test]
    fn perfect_alignment_beats_random() {
        let img = unit_rows(8, 16, 1);
        let txt = unit_rows(8, 16, 2);
        let random = clip_contrastive(&img, &txt, 0.0).loss;
        let aligned = clip_contrastive(&img, &img.clone(), 0.0).loss;
        assert!(
            aligned < random,
            "aligned pairs must score lower loss: {aligned} vs {random}"
        );
        let hot = clip_contrastive(&img, &img.clone(), init_log_scale());
        assert!(hot.loss < aligned, "sharper temperature separates further");
        assert_eq!(hot.acc, 1.0);
    }

    #[test]
    fn loss_is_near_log_b_for_orthogonal_embeddings() {
        // embed dim ≫ batch: random unit rows are nearly orthogonal, so at
        // scale 1 the logits are nearly uniform and loss ≈ ln(B)
        let img = unit_rows(4, 512, 3);
        let txt = unit_rows(4, 512, 4);
        let out = clip_contrastive(&img, &txt, 0.0);
        assert!((out.loss - (4.0f32).ln()).abs() < 0.15, "loss {}", out.loss);
    }

    /// Full finite-difference check of all three gradients.
    #[test]
    fn gradients_match_finite_difference() {
        let img = unit_rows(5, 7, 10);
        let txt = unit_rows(5, 7, 11);
        let ls = 1.2f32;
        let out = clip_contrastive(&img, &txt, ls);
        let h = 1e-3;
        for i in 0..img.data.len() {
            let mut p = img.clone();
            p.data[i] += h;
            let mut m = img.clone();
            m.data[i] -= h;
            let fd = (clip_contrastive(&p, &txt, ls).loss
                - clip_contrastive(&m, &txt, ls).loss)
                / (2.0 * h);
            assert!(
                (out.d_img.data[i] - fd).abs() < 2e-3,
                "d_img[{i}]: {} vs {fd}",
                out.d_img.data[i]
            );
        }
        for i in 0..txt.data.len() {
            let mut p = txt.clone();
            p.data[i] += h;
            let mut m = txt.clone();
            m.data[i] -= h;
            let fd = (clip_contrastive(&img, &p, ls).loss
                - clip_contrastive(&img, &m, ls).loss)
                / (2.0 * h);
            assert!(
                (out.d_txt.data[i] - fd).abs() < 2e-3,
                "d_txt[{i}]: {} vs {fd}",
                out.d_txt.data[i]
            );
        }
        let fd = (clip_contrastive(&img, &txt, ls + h).loss
            - clip_contrastive(&img, &txt, ls - h).loss)
            / (2.0 * h);
        assert!(
            (out.d_log_scale - fd).abs() < 2e-3,
            "d_log_scale {} vs {fd}",
            out.d_log_scale
        );
    }

    #[test]
    fn scale_clamp_zeroes_its_gradient() {
        let img = unit_rows(3, 8, 20);
        let txt = unit_rows(3, 8, 21);
        let out = clip_contrastive(&img, &txt, 6.0); // exp(6) > 100
        assert_eq!(out.d_log_scale, 0.0);
        assert!(out.loss.is_finite());
    }

    /// Structural invariant: `Σ_ij dL_ij = 0` (each row of P and each
    /// column of Q sums to 1, against the 2B identity subtractions).
    /// With every text row identical (= t), row i of `d_img` is
    /// `s·(Σ_j dL_ij)·t`, so the sum over all `d_img` rows equals
    /// `s·(Σ_ij dL_ij)·t` — it must vanish per column.  A wrong delta
    /// constant in the dlogits loop breaks this immediately.
    #[test]
    fn gradient_sums_vanish() {
        let img = unit_rows(6, 12, 30);
        let t_row = unit_rows(1, 12, 31);
        let mut txt = Matrix::zeros(6, 12);
        for r in 0..6 {
            txt.row_mut(r).copy_from_slice(t_row.row(0));
        }
        let out = clip_contrastive(&img, &txt, 1.0);
        for c in 0..12 {
            let col_sum: f32 = (0..6).map(|r| out.d_img.at(r, c)).sum();
            assert!(col_sum.abs() < 1e-4, "d_img column {c} sums to {col_sum}");
        }
    }
}
