//! The native training loop — the PJRT-free end-to-end path.
//!
//! Per step:
//! 1. synthesize the next batch ([`crate::data`], honouring the shift
//!    schedule — the same spike trigger the PJRT path uses),
//! 2. forward both towers over `grad_shards` fixed batch shards on
//!    [`crate::util::threads::par_map`] workers,
//! 3. compute the symmetric InfoNCE loss *globally* (full-batch in-batch
//!    negatives — sharding never changes the math),
//! 4. backward each shard in parallel, then sum shard gradients in shard
//!    order,
//! 5. optionally clip the global gradient norm,
//! 6. step the optimizer (AdamW / StableAdamW / Lion via
//!    `coordinator::common::build_optimizer`) with the warmup+cosine LR,
//!    collecting per-tensor `RMS_t`,
//! 7. log to the metrics sink (JSONL) with per-step RMS probes.
//!
//! **Determinism**: the shard partition depends only on `batch` and
//! `grad_shards` (never on the worker count), every per-element reduction
//! in the substrate runs sequentially inside one worker, and shard
//! gradients are summed in shard order — so a step's gradients are
//! bit-identical under any `SWITCHBACK_THREADS` setting (tested below).

use super::loss::clip_contrastive;
use super::model::ClipTrainModel;
use crate::ckpt::{self, TrainCheckpoint};
use crate::config::TrainHyper;
use crate::coordinator::common::{build_optimizer, spike_cfg, tail_mean_loss};
use crate::coordinator::eval::nearest_class_accuracy;
use crate::data::{Batch, DataConfig, Shift, SyntheticClip};
use crate::optim::schedules::LrSchedule;
use crate::optim::{clip_global_norm, under_estimation_ratio, OptimizerState};
use crate::serve::EncoderConfig;
use crate::telemetry::spikes::DEDUP_WINDOW;
use crate::telemetry::{
    detect_loss_spikes, detect_rms_spikes, MetricsSink, SpikeConfig, StepRecord,
    TensorProbe,
};
use crate::tensor::Matrix;
use crate::trace::{self, FlightFrame, FlightRecorder};
use crate::util::json::ObjWriter;
use crate::util::threads::par_map;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Live telemetry hooks armed by `--telemetry-addr`: the trainer
/// publishes into these every step; the HTTP plane
/// ([`crate::trace::telemetry_http`]) reads them.
#[derive(Clone)]
pub struct LiveHooks {
    /// shared flight recorder behind `/flight` — the trainer pushes every
    /// step's frame, a scrape dumps the current window non-destructively
    pub flight: Arc<Mutex<FlightRecorder>>,
    /// last completed step (0 until the first step lands) — train-mode
    /// `/readyz` flips ready once this is > 0
    pub step_done: Arc<AtomicU64>,
}

impl LiveHooks {
    pub fn new(flight_window: usize) -> Self {
        Self {
            flight: Arc::new(Mutex::new(FlightRecorder::new(flight_window))),
            step_done: Arc::new(AtomicU64::new(0)),
        }
    }

    /// `/flight` body: the recorder's current window, or `None` while
    /// empty (the endpoint answers 404 until the first frame lands).
    pub fn flight_json(&self) -> Option<String> {
        let fr = self.flight.lock().unwrap_or_else(|e| e.into_inner());
        (!fr.is_empty()).then(|| fr.dump_json("live_scrape", fr.last_step()))
    }
}

impl std::fmt::Debug for LiveHooks {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "LiveHooks(step_done={})", self.step_done.load(Ordering::Relaxed))
    }
}

/// One native training run's knobs.
#[derive(Debug, Clone)]
pub struct NativeTrainConfig {
    /// optimizer/schedule hyperparameters (shared with the PJRT path)
    pub hyper: TrainHyper,
    /// model shape + precision kind (shared with the serving encoder)
    pub encoder: EncoderConfig,
    pub batch: usize,
    /// fixed data-parallel shard count for gradient accumulation (the
    /// partition is thread-count independent; workers come from
    /// `SWITCHBACK_THREADS`)
    pub grad_shards: usize,
    /// scheduled distribution shifts (the spike trigger)
    pub shifts: Vec<Shift>,
    /// log grad probes every N steps (0 = never)
    pub probe_every: u64,
    /// JSONL metrics path (None = in-memory only)
    pub metrics_path: Option<String>,
    /// examples per concept for the final zero-shot eval (0 = skip)
    pub eval_per_concept: usize,
    /// write a disk snapshot every N steps (0 = off; needs `ckpt_dir`)
    pub ckpt_every: u64,
    /// snapshot directory for `--ckpt-every` / the final-state snapshot
    pub ckpt_dir: Option<String>,
    /// retention: keep only the newest K disk snapshots
    pub ckpt_keep: usize,
    /// shard count per snapshot (`--ckpt-shards`): ≤ 1 writes the v1
    /// single file, ≥ 2 the v2 manifest-of-shards directory (shards
    /// encoded/CRC'd/written in parallel)
    pub ckpt_shards: usize,
    /// background saves (`--ckpt-async`): capture the state at the step
    /// boundary and serialize + write it on a dedicated saver thread so
    /// the step loop never blocks on disk; joined (and error-checked)
    /// before the run reports complete
    pub ckpt_async: bool,
    /// spike-rollback guard: when the loss spikes, restore the last
    /// in-memory snapshot (model + optimizer) and skip the offending
    /// shard window instead of training through it
    pub rollback_on_spike: bool,
    /// guard deviation threshold in trailing-window standard deviations
    /// (`--spike-sigma`; default: the paper's 3.2σ, Appendix D)
    pub spike_sigma: f32,
    /// steps the guard stays quiet after firing while the loss baseline
    /// adapts (`--spike-cooldown`; default 3× the Appendix-D dedup
    /// window = 30)
    pub spike_cooldown: u64,
    /// flight-recorder forensic dump path (`--flight-out`; None = recorder
    /// off).  When the rollback guard fires — or, failing that, the
    /// post-hoc loss-spike detector finds a spike — the last
    /// `flight_window` steps of full-fidelity probes (per-tensor RMS_t
    /// and the g²/v under-estimation ratio) are written here as JSON
    pub flight_path: Option<String>,
    /// flight-recorder ring capacity in steps (`--flight-window`)
    pub flight_window: usize,
    /// live telemetry hooks (`--telemetry-addr`; None = no live plane).
    /// When set, the trainer pushes every step's flight frame into the
    /// shared recorder, advances the step-done counter for `/readyz`,
    /// and publishes live gauges (loss/lr/grad-norm plus per-layer
    /// quant-error/clip-rate and `g²/v` under-estimation at the probe
    /// cadence) into [`crate::trace::global`]
    pub live: Option<LiveHooks>,
}

impl NativeTrainConfig {
    /// Small-model defaults: big enough that SwitchBack's int8 GEMMs do
    /// real work, small enough that a 50-step smoke runs in seconds.
    pub fn preset(kind: crate::nn::LinearKind, steps: u64) -> Self {
        let hyper = TrainHyper {
            lr: 1e-3,
            weight_decay: 0.1,
            seed: 42,
            ..TrainHyper::preset(steps)
        };
        Self {
            hyper,
            encoder: EncoderConfig {
                kind,
                dim: 64,
                heads: 4,
                blocks: 2,
                embed_dim: 32,
                patches: 8,
                patch_dim: 32,
                text_seq: 8,
                vocab: 256,
                seed: 42,
            },
            batch: 32,
            grad_shards: 4,
            shifts: vec![],
            probe_every: 1,
            metrics_path: None,
            eval_per_concept: 2,
            ckpt_every: 0,
            ckpt_dir: None,
            ckpt_keep: 3,
            ckpt_shards: 1,
            ckpt_async: false,
            rollback_on_spike: false,
            spike_sigma: crate::telemetry::DEFAULT_LOSS_SIGMA,
            spike_cooldown: 3 * DEDUP_WINDOW,
            flight_path: None,
            flight_window: 64,
            live: None,
        }
    }

    /// The synthetic-corpus config this run trains on — the single place
    /// the data seed is derived from the run seed.  `pipeline`'s eval
    /// rebuilds the stream through this same constructor, so the two can
    /// never drift (a drifted stream would silently eval on a
    /// distribution the model never saw).
    pub fn data_config(&self) -> DataConfig {
        let e = &self.encoder;
        DataConfig {
            shifts: self.shifts.clone(),
            ..DataConfig::for_model(
                e.patches,
                e.patch_dim,
                e.text_seq,
                e.vocab,
                self.hyper.seed.wrapping_add(0x5EED),
            )
        }
    }

    /// JSON echo of one run's config (per-run logs: includes this run's
    /// kind and optimizer).
    pub fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_str("kind", self.encoder.kind.label());
        self.hyper.write_json(&mut w);
        self.write_shape_json(&mut w);
        w.finish()
    }

    /// JSON echo of the run-matrix-invariant slice (BENCH_train.json's
    /// `config` block): shape + schedule only.  Kind and optimizer vary
    /// across the matrix and live on each `results` entry instead.
    pub fn shared_to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_u64("steps", self.hyper.steps)
            .field_u64("warmup", self.hyper.warmup)
            .field_f32("lr", self.hyper.lr)
            .field_f32("weight_decay", self.hyper.weight_decay)
            .field_f32("beta1", self.hyper.beta1)
            .field_f32("beta2", self.hyper.beta2)
            .field_u64("seed", self.hyper.seed);
        if let Some(l) = self.hyper.beta2_lambda {
            w.field_f32("beta2_lambda", l);
        }
        if let Some(c) = self.hyper.grad_clip {
            w.field_f32("grad_clip", c);
        }
        self.write_shape_json(&mut w);
        w.finish()
    }

    fn write_shape_json(&self, w: &mut ObjWriter) {
        w.field_u64("batch", self.batch as u64)
            .field_u64("grad_shards", self.grad_shards as u64)
            .field_u64("dim", self.encoder.dim as u64)
            .field_u64("heads", self.encoder.heads as u64)
            .field_u64("blocks", self.encoder.blocks as u64)
            .field_u64("embed_dim", self.encoder.embed_dim as u64)
            .field_u64("patches", self.encoder.patches as u64)
            .field_u64("patch_dim", self.encoder.patch_dim as u64)
            .field_u64("text_seq", self.encoder.text_seq as u64)
            .field_u64("vocab", self.encoder.vocab as u64);
        if !self.shifts.is_empty() {
            w.field_u64("n_shifts", self.shifts.len() as u64);
        }
    }
}

/// Output of one fused forward + loss + backward pass.
pub struct StepOutput {
    pub loss: f32,
    /// in-batch image→text retrieval accuracy
    pub acc: f32,
    /// flat per-tensor gradients aligned with the model's param layout
    pub grads: Vec<Vec<f32>>,
    pub forward_ms: f64,
    pub loss_ms: f64,
    pub backward_ms: f64,
}

/// Contiguous shard ranges over `batch` examples — a pure function of
/// `(batch, shards)`, never of the worker count (the determinism anchor).
fn shard_ranges(batch: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.clamp(1, batch.max(1));
    let per = batch.div_ceil(shards);
    (0..shards)
        .map(|s| (s * per, ((s + 1) * per).min(batch)))
        .filter(|(lo, hi)| lo < hi)
        .collect()
}

/// One training step's compute: sharded forward, global contrastive loss,
/// sharded backward, ordered gradient accumulation.
pub fn forward_backward(
    model: &ClipTrainModel,
    batch: &Batch,
    grad_shards: usize,
) -> StepOutput {
    let c = &model.cfg;
    let n = batch.len();
    assert!(n > 0, "empty batch");
    let ranges = shard_ranges(n, grad_shards);
    let img_row = c.patches * c.patch_dim;
    assert_eq!(batch.images.len(), n * img_row, "image payload shape");

    // 1) sharded forward (shard slices come straight from the batch — no
    //    full-batch intermediate copy on the hot path)
    let t0 = trace::clock();
    let caches = par_map(ranges.len(), |s| {
        let (lo, hi) = ranges[s];
        let rows = (hi - lo) * c.patches;
        let sub = Matrix::from_vec(
            rows,
            c.patch_dim,
            batch.images[lo * img_row..hi * img_row].to_vec(),
        );
        let toks = &batch.tokens[lo * c.text_seq..hi * c.text_seq];
        model.forward(&sub, toks)
    });
    let forward_ms = t0.elapsed().as_secs_f64() * 1e3;

    // 2) global loss over the assembled full-batch embeddings
    let t1 = trace::clock();
    let e = c.embed_dim;
    let mut img_z = Matrix::zeros(n, e);
    let mut txt_z = Matrix::zeros(n, e);
    for (cache, &(lo, hi)) in caches.iter().zip(&ranges) {
        img_z.data[lo * e..hi * e].copy_from_slice(&cache.img_z().data);
        txt_z.data[lo * e..hi * e].copy_from_slice(&cache.txt_z().data);
    }
    let out = clip_contrastive(&img_z, &txt_z, model.log_scale);
    let loss_ms = t1.elapsed().as_secs_f64() * 1e3;

    // 3) sharded backward + ordered accumulation
    let t2 = trace::clock();
    let shard_grads = par_map(ranges.len(), |s| {
        let (lo, hi) = ranges[s];
        let rows = hi - lo;
        let d_img = Matrix::from_vec(rows, e, out.d_img.data[lo * e..hi * e].to_vec());
        let d_txt = Matrix::from_vec(rows, e, out.d_txt.data[lo * e..hi * e].to_vec());
        model.backward(&caches[s], &d_img, &d_txt)
    });
    let mut grads: Vec<Vec<f32>> = shard_grads
        .into_iter()
        .reduce(|mut acc, shard| {
            for (a, s) in acc.iter_mut().zip(&shard) {
                for (av, &sv) in a.iter_mut().zip(s) {
                    *av += sv;
                }
            }
            acc
        })
        .expect("at least one shard");
    let last = grads.len() - 1;
    grads[last][0] = out.d_log_scale; // global, not per-shard
    let backward_ms = t2.elapsed().as_secs_f64() * 1e3;

    StepOutput {
        loss: out.loss,
        acc: out.acc,
        grads,
        forward_ms,
        loss_ms,
        backward_ms,
    }
}

/// Accumulated wall-time breakdown over a run (milliseconds).
#[derive(Debug, Clone, Default)]
pub struct StepTiming {
    pub data_ms: f64,
    pub forward_ms: f64,
    pub loss_ms: f64,
    pub backward_ms: f64,
    pub optim_ms: f64,
    pub total_ms: f64,
}

impl StepTiming {
    fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_f32("data", self.data_ms as f32)
            .field_f32("forward", self.forward_ms as f32)
            .field_f32("loss", self.loss_ms as f32)
            .field_f32("backward", self.backward_ms as f32)
            .field_f32("optim", self.optim_ms as f32)
            .field_f32("total", self.total_ms as f32);
        w.finish()
    }
}

/// Outcome of one native run.
pub struct NativeRunResult {
    pub kind: &'static str,
    pub optimizer: &'static str,
    pub first_loss: f32,
    pub final_loss: f32,
    /// mean loss over the last 10% of steps (robust curve endpoint)
    pub tail_loss: f32,
    /// in-batch retrieval accuracy at the final step
    pub final_acc: f32,
    pub steps_per_sec: f32,
    pub loss_spikes: usize,
    pub rms_spikes: usize,
    pub diverged: bool,
    pub zero_shot_acc: Option<f32>,
    pub timing: StepTiming,
    pub sink: MetricsSink,
    /// step this run resumed from (None = fresh run)
    pub resumed_from: Option<u64>,
    /// steps at which the spike-rollback guard fired
    pub rollback_steps: Vec<u64>,
    /// disk snapshots written (`--ckpt-every`)
    pub snapshots: usize,
    /// total bytes and wall seconds spent writing snapshots
    pub ckpt_bytes: u64,
    pub ckpt_save_secs: f64,
    /// estimated span-tracer cost as a percentage of mean step wall time
    /// (spans recorded per step × calibrated per-span cost / step time);
    /// gated by `benchdiff` so instrumentation creep is caught in CI
    pub trace_overhead_pct: f32,
    /// path of the forensic flight dump written this run, if a spike
    /// trigger fired while the recorder was on (`--flight-out`)
    pub flight_dump: Option<String>,
}

impl NativeRunResult {
    pub fn print(&self) {
        println!(
            "[{:<12}/{:<13}] loss {:.4} → {:.4} (tail {:.4})  acc {:4.0}%  \
             {:5.1} steps/s  spikes {}/{}{}",
            self.kind,
            self.optimizer,
            self.first_loss,
            self.final_loss,
            self.tail_loss,
            100.0 * self.final_acc,
            self.steps_per_sec,
            self.loss_spikes,
            self.rms_spikes,
            if self.diverged { "  [DIVERGED]" } else { "" },
        );
        if let Some(acc) = self.zero_shot_acc {
            println!("               zero-shot acc {:.1}%", 100.0 * acc);
        }
        if let Some(from) = self.resumed_from {
            println!("               resumed from step {from}");
        }
        if !self.rollback_steps.is_empty() {
            println!(
                "               spike rollbacks: {} (at steps {:?})",
                self.rollback_steps.len(),
                self.rollback_steps
            );
        }
        if let Some(p) = &self.flight_dump {
            println!("               flight dump: {p}");
        }
    }

    fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_str("kind", self.kind)
            .field_str("optimizer", self.optimizer)
            .field_f32("first_loss", self.first_loss)
            .field_f32("final_loss", self.final_loss)
            .field_f32("tail_loss", self.tail_loss)
            .field_f32("final_acc", self.final_acc)
            .field_f32("steps_per_sec", self.steps_per_sec)
            .field_u64("loss_spikes", self.loss_spikes as u64)
            .field_u64("rms_spikes", self.rms_spikes as u64)
            .field_bool("diverged", self.diverged)
            .field_u64("rollbacks", self.rollback_steps.len() as u64)
            .field_f32("trace_overhead_pct", self.trace_overhead_pct)
            .field_raw("time_ms", &self.timing.to_json());
        if let Some(p) = &self.flight_dump {
            w.field_str("flight_dump", p);
        }
        if let Some(acc) = self.zero_shot_acc {
            w.field_f32("zero_shot_acc", acc);
        }
        if let Some(from) = self.resumed_from {
            w.field_u64("resumed_from", from);
        }
        if self.snapshots > 0 {
            w.field_u64("snapshots", self.snapshots as u64)
                .field_u64("ckpt_bytes", self.ckpt_bytes)
                .field_f32(
                    "ckpt_save_mb_s",
                    (self.ckpt_bytes as f64 / 1e6 / self.ckpt_save_secs.max(1e-9)) as f32,
                );
        }
        w.finish()
    }
}

/// Online loss-spike detector driving the rollback guard — the streaming
/// form of [`detect_loss_spikes`]: a trailing-window mean/σ deviation test
/// with the paper's two-deviations-within-10 confirmation, plus a
/// cooldown so a permanent distribution shift cannot thrash the guard
/// while the running baseline adapts.
struct RollbackGuard {
    cfg: SpikeConfig,
    /// post-fire quiet period in steps (`--spike-cooldown`)
    cooldown: u64,
    history: Vec<f32>,
    last_deviation: Option<u64>,
    cooldown_until: u64,
}

impl RollbackGuard {
    fn new(cfg: SpikeConfig, cooldown: u64) -> Self {
        Self {
            cfg,
            cooldown,
            history: vec![],
            last_deviation: None,
            cooldown_until: 0,
        }
    }

    /// An unconfirmed deviation is pending: the trainer must not refresh
    /// its rollback snapshot while armed, or a confirmation arriving up to
    /// [`DEDUP_WINDOW`] steps later would "roll back" onto a snapshot that
    /// already contains the spiked updates.
    fn armed(&self) -> bool {
        self.last_deviation.is_some()
    }

    /// Observe step `step`'s loss; returns `true` when a confirmed spike
    /// should trigger a rollback *now*.
    fn observe(&mut self, step: u64, loss: f32) -> bool {
        // a deviation that was never confirmed within the window is stale:
        // disarm so the snapshot cadence can resume (see `armed`)
        if self
            .last_deviation
            .is_some_and(|d| step.saturating_sub(d) > DEDUP_WINDOW)
        {
            self.last_deviation = None;
        }
        let deviation = if self.history.len() < 5 || step < self.cfg.burn_in {
            false
        } else if !loss.is_finite() {
            true
        } else {
            let lo = self.history.len().saturating_sub(self.cfg.stat_window);
            let hist = &self.history[lo..];
            let n = hist.len() as f64;
            let mean = hist.iter().map(|&v| v as f64).sum::<f64>() / n;
            let var =
                hist.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
            let std = var.sqrt().max(1e-12);
            (loss as f64) > mean + self.cfg.loss_sigma as f64 * std
        };
        // finite spiked losses still enter the history — after a real
        // distribution shift the baseline must adapt or the guard would
        // fire forever.  Non-finite losses stay out: one NaN would poison
        // the window mean and blind the detector for stat_window steps.
        if loss.is_finite() {
            self.history.push(loss);
            // bound the baseline: only the trailing stat_window values are
            // ever read (amortized O(1) trim for multi-million-step runs)
            if self.history.len() > 2 * self.cfg.stat_window.max(1) {
                let excess = self.history.len() - self.cfg.stat_window;
                self.history.drain(..excess);
            }
        }
        if !deviation || step < self.cooldown_until {
            return false;
        }
        match self.last_deviation {
            Some(prev) if step.saturating_sub(prev) <= DEDUP_WINDOW => {
                self.last_deviation = None;
                self.cooldown_until = step + self.cooldown;
                true
            }
            _ => {
                self.last_deviation = Some(step);
                false
            }
        }
    }
}

/// The native trainer: owns the model, the data stream and the config.
pub struct NativeTrainer {
    cfg: NativeTrainConfig,
    model: ClipTrainModel,
    data: SyntheticClip,
    /// step the model/optimizer/data state corresponds to (resume cursor)
    start_step: u64,
    /// optimizer state pending import at the top of [`Self::run`]
    resume_opt: Option<OptimizerState>,
    /// full state capture at the end of the last [`Self::run`]
    final_ckpt: Option<TrainCheckpoint>,
}

impl NativeTrainer {
    pub fn new(cfg: NativeTrainConfig) -> Self {
        let data = SyntheticClip::new(cfg.data_config());
        let model = ClipTrainModel::new(cfg.encoder.clone());
        Self {
            cfg,
            model,
            data,
            start_step: 0,
            resume_opt: None,
            final_ckpt: None,
        }
    }

    pub fn model(&self) -> &ClipTrainModel {
        &self.model
    }

    /// State capture at the end of the last completed [`Self::run`] —
    /// what `pipeline` serves and what the final disk snapshot contains.
    pub fn final_checkpoint(&self) -> Option<&TrainCheckpoint> {
        self.final_ckpt.as_ref()
    }

    /// Restore a checkpoint into this trainer so the next [`Self::run`]
    /// continues bit-identically from `ck.step`.  Fails closed on any
    /// mismatch the math depends on — a resume under different
    /// shape/hyper/schedule would silently diverge from the original run.
    ///
    /// Scope of the contract: the *training math* (weights, optimizer
    /// moments, data draws, schedule) is bit-identical.  The spike
    /// `RollbackGuard` is a reactive intervention, not training math —
    /// its online loss history / cooldown are not checkpointed, so under
    /// `rollback_on_spike` a resumed detector restarts cold and guard
    /// *decisions* within `stat_window` of the resume point may differ
    /// from the uninterrupted run's (the CLI prints a note).
    pub fn restore(&mut self, ck: &TrainCheckpoint) -> Result<()> {
        let e = &self.cfg.encoder;
        let c = &ck.encoder;
        if (c.dim, c.heads, c.blocks, c.embed_dim)
            != (e.dim, e.heads, e.blocks, e.embed_dim)
            || (c.patches, c.patch_dim, c.text_seq, c.vocab)
                != (e.patches, e.patch_dim, e.text_seq, e.vocab)
            || c.kind != e.kind
            || c.seed != e.seed
        {
            bail!(
                "checkpoint model {:?} does not match this run's model {:?}",
                c,
                e
            );
        }
        let h = &self.cfg.hyper;
        let k = &ck.hyper;
        if (k.steps, k.warmup, k.seed, k.optimizer)
            != (h.steps, h.warmup, h.seed, h.optimizer)
            || k.lr.to_bits() != h.lr.to_bits()
            || k.weight_decay.to_bits() != h.weight_decay.to_bits()
            || k.beta1.to_bits() != h.beta1.to_bits()
            || k.beta2.to_bits() != h.beta2.to_bits()
            || k.beta2_lambda.map(f32::to_bits) != h.beta2_lambda.map(f32::to_bits)
            || k.grad_clip.map(f32::to_bits) != h.grad_clip.map(f32::to_bits)
        {
            bail!(
                "checkpoint hyperparameters {:?} do not match this run's {:?} \
                 — resume must use the original schedule",
                k,
                h
            );
        }
        let same_shifts = ck.shifts.len() == self.cfg.shifts.len()
            && ck.shifts.iter().zip(&self.cfg.shifts).all(|(a, b)| {
                a.at_step == b.at_step
                    && a.image_gain.to_bits() == b.image_gain.to_bits()
                    && a.remap_concepts == b.remap_concepts
            });
        if !same_shifts {
            bail!("checkpoint shift schedule does not match this run's");
        }
        if (ck.batch, ck.grad_shards) != (self.cfg.batch, self.cfg.grad_shards) {
            bail!(
                "checkpoint was trained with batch {} / {} shards, this run \
                 uses {} / {} — the data draws and summation order would differ",
                ck.batch,
                ck.grad_shards,
                self.cfg.batch,
                self.cfg.grad_shards
            );
        }
        if ck.step >= h.steps {
            bail!(
                "checkpoint is at step {} of a {}-step run — nothing to resume",
                ck.step,
                h.steps
            );
        }
        self.model.load_params(&ck.params);
        self.data
            .restore(&ck.data)
            .map_err(|e| anyhow::anyhow!("data cursor: {e}"))?;
        self.start_step = ck.step;
        self.resume_opt = Some(ck.opt.clone());
        Ok(())
    }

    /// Assemble a [`TrainCheckpoint`] from the live training state.
    fn capture(
        &self,
        step: u64,
        params: &[Vec<f32>],
        opt_state: OptimizerState,
    ) -> TrainCheckpoint {
        TrainCheckpoint {
            step,
            encoder: self.cfg.encoder.clone(),
            hyper: self.cfg.hyper.clone(),
            shifts: self.cfg.shifts.clone(),
            batch: self.cfg.batch,
            grad_shards: self.cfg.grad_shards,
            param_names: self
                .model
                .param_metas()
                .into_iter()
                .map(|m| m.name)
                .collect(),
            params: params.to_vec(),
            opt: opt_state,
            data: self.data.cursor(),
        }
    }

    /// Run from the current state (step `start_step`, 0 for a fresh
    /// trainer) to the configured number of steps.
    pub fn run(&mut self, verbose: bool) -> Result<NativeRunResult> {
        let h = self.cfg.hyper.clone();
        if self.start_step >= h.steps {
            bail!("start step {} >= total steps {}", self.start_step, h.steps);
        }
        let metas = self.model.param_metas();
        let mut params = self.model.collect_params();
        let sizes: Vec<usize> = params.iter().map(|p| p.len()).collect();
        let mut opt = build_optimizer(&h, &metas, &sizes);
        if let Some(st) = self.resume_opt.take() {
            opt.import_state(&st)
                .map_err(|e| anyhow::anyhow!("optimizer state: {e}"))?;
        }
        let schedule = LrSchedule::new(h.lr, h.warmup, h.steps);
        let (pe_idx, mid_idx) = self.model.probe_indices();
        let pe_name = metas[pe_idx].name.clone();
        let mid_name = metas[mid_idx].name.clone();

        let mut sink = match &self.cfg.metrics_path {
            Some(p) => MetricsSink::to_file(Path::new(p))?,
            None => MetricsSink::memory(),
        };
        let mut timing = StepTiming::default();
        let mut first_loss = f32::NAN;
        let mut final_acc = 0.0f32;
        let mut diverged = false;

        // --- checkpoint / rollback machinery -------------------------
        let ckpt_dir = self.cfg.ckpt_dir.as_ref().map(std::path::PathBuf::from);
        let disk_every = if ckpt_dir.is_some() { self.cfg.ckpt_every } else { 0 };
        // --ckpt-async: a dedicated saver thread pays for serialization +
        // CRC + disk; the step loop only pays the step-boundary capture
        let mut saver = (disk_every > 0 && self.cfg.ckpt_async)
            .then(ckpt::AsyncSaver::spawn);
        // the guard restores from an in-memory snapshot; refresh it on the
        // disk cadence when one is configured, else every dedup window
        let mem_every = if self.cfg.rollback_on_spike {
            if disk_every > 0 {
                disk_every
            } else {
                DEDUP_WINDOW
            }
        } else {
            0
        };
        let mut guard = self.cfg.rollback_on_spike.then(|| {
            // the guard's threshold is tunable (--spike-sigma); the
            // post-hoc spike *reporting* below stays at the paper's 3.2σ
            // so BENCH_train spike counts remain comparable across runs
            let cfg = SpikeConfig {
                loss_sigma: self.cfg.spike_sigma,
                ..spike_cfg(h.steps)
            };
            RollbackGuard::new(cfg, self.cfg.spike_cooldown)
        });
        let mut mem_snap: Option<(u64, Vec<Vec<f32>>, OptimizerState)> = self
            .cfg
            .rollback_on_spike
            .then(|| (self.start_step, params.clone(), opt.export_state()));
        let mut rollback_steps: Vec<u64> = vec![];
        let mut snapshots = 0usize;
        let mut ckpt_bytes = 0u64;
        let mut ckpt_save_secs = 0.0f64;
        let resumed_from = (self.start_step > 0).then_some(self.start_step);
        // the recorder is shared with the `/flight` endpoint when the
        // live plane is armed; otherwise it is private to this run.
        // Dumping to disk still requires --flight-out either way.
        let flight: Option<Arc<Mutex<FlightRecorder>>> = match (&self.cfg.live, &self.cfg.flight_path) {
            (Some(hooks), _) => Some(Arc::clone(&hooks.flight)),
            (None, Some(_)) => {
                Some(Arc::new(Mutex::new(FlightRecorder::new(self.cfg.flight_window))))
            }
            (None, None) => None,
        };
        let mut flight_dump: Option<String> = None;
        // live gauges are hoisted handles: one relaxed store per step
        let live_gauges = self.cfg.live.as_ref().map(|_| {
            let g = trace::global();
            (
                g.gauge("train.step"),
                g.gauge("train.loss"),
                g.gauge("train.grad_norm"),
                g.gauge("train.lr"),
            )
        });
        let spans_before = trace::spans_recorded();
        let run_t0 = trace::clock();

        for step in self.start_step + 1..=h.steps {
            let _step_sp = trace::span_n("train.step", "train", step as u32);
            let step_t0 = trace::clock();
            let batch = {
                let _sp = trace::span("train.data", "train");
                self.data.next_batch(self.cfg.batch)
            };
            timing.data_ms += step_t0.elapsed().as_secs_f64() * 1e3;

            let out = forward_backward(&self.model, &batch, self.cfg.grad_shards);
            timing.forward_ms += out.forward_ms;
            timing.loss_ms += out.loss_ms;
            timing.backward_ms += out.backward_ms;
            // phase timings come back from forward_backward; turn them
            // into retroactive spans (they ran back-to-back ending now)
            // rather than paying a second clock inside the hot path
            let fb_end = trace::now_ns();
            let f_ns = (out.forward_ms * 1e6) as u64;
            let l_ns = (out.loss_ms * 1e6) as u64;
            let b_ns = (out.backward_ms * 1e6) as u64;
            let b_start = fb_end.saturating_sub(b_ns);
            let l_start = b_start.saturating_sub(l_ns);
            let f_start = l_start.saturating_sub(f_ns);
            trace::event_at("train.forward", "train", f_start, f_ns, step as u32);
            trace::event_at("train.loss", "train", l_start, l_ns, step as u32);
            trace::event_at("train.backward", "train", b_start, b_ns, step as u32);
            if step == self.start_step + 1 {
                first_loss = out.loss;
            }
            final_acc = out.acc;

            // the guard sees the loss before the update is applied: a
            // confirmed spike reverts model+optimizer to the last snapshot
            // and skips this shard window entirely (the data stream has
            // already moved past it)
            let rolled_back =
                guard.as_mut().is_some_and(|g| g.observe(step, out.loss));
            if !rolled_back && (!out.loss.is_finite() || out.loss > 50.0) {
                diverged = true;
            }

            let mut grads = out.grads;
            let clip_sp = trace::span("train.clip", "train");
            let grad_norm = {
                let mut ss = 0.0f64;
                for g in &grads {
                    for &v in g {
                        if v.is_finite() {
                            ss += (v as f64) * (v as f64);
                        }
                    }
                }
                ss.sqrt() as f32
            };
            if let Some(max_norm) = h.grad_clip {
                clip_global_norm(&mut grads, max_norm);
            }
            drop(clip_sp);

            let t_opt = trace::clock();
            let opt_sp = trace::span("train.optim", "train");
            let lr = schedule.at(step);
            let stats = if rolled_back {
                let (snap_step, snap_params, snap_opt) =
                    mem_snap.as_ref().expect("rollback guard implies a snapshot");
                for (dst, src) in params.iter_mut().zip(snap_params) {
                    dst.copy_from_slice(src);
                }
                self.model.load_params(&params);
                opt.import_state(snap_opt)
                    .map_err(|e| anyhow::anyhow!("rollback: {e}"))?;
                rollback_steps.push(step);
                if verbose {
                    println!(
                        "  step {step:>5}  loss {:8.4}  SPIKE — rolled back to \
                         step-{snap_step} snapshot, shard window skipped",
                        out.loss
                    );
                }
                crate::optim::StepStats::empty(params.len())
            } else {
                let stats = opt.step(&mut params, &grads, lr, None);
                self.model.load_params(&params);
                stats
            };
            drop(opt_sp);
            timing.optim_ms += t_opt.elapsed().as_secs_f64() * 1e3;

            // never refresh the rollback snapshot while a deviation is
            // pending confirmation — the pending spike's update is already
            // in `params`, and snapshotting it would make the upcoming
            // rollback restore the poisoned state it means to discard
            let guard_armed = guard.as_ref().is_some_and(|g| g.armed());
            if mem_every > 0 && step % mem_every == 0 && !guard_armed {
                mem_snap = Some((step, params.clone(), opt.export_state()));
            }
            if disk_every > 0 && (step % disk_every == 0 || step == h.steps) {
                let dir = ckpt_dir.as_ref().expect("disk_every implies ckpt_dir");
                let path = ckpt::snapshot_path(dir, step);
                // the capture *is* the step-boundary copy (an O(bytes)
                // memcpy of params + moments + cursor); everything after
                // it — encode, CRC, disk — can leave the step loop
                let ck = {
                    let _sp = trace::span("train.ckpt_capture", "train");
                    self.capture(step, &params, opt.export_state())
                };
                match &saver {
                    Some(sv) => {
                        sv.enqueue(path, ck, self.cfg.ckpt_shards);
                        // retention must not race the saver: in-flight
                        // paths are excluded from count and deletion
                        ckpt::prune_snapshots_guarded(
                            dir,
                            self.cfg.ckpt_keep,
                            &sv.in_flight(),
                        );
                    }
                    None => {
                        let st =
                            ckpt::save_sharded(&path, &ck, self.cfg.ckpt_shards)?;
                        snapshots += 1;
                        ckpt_bytes += st.bytes;
                        ckpt_save_secs += st.secs;
                        ckpt::prune_snapshots(dir, self.cfg.ckpt_keep);
                    }
                }
            }

            let step_ms = step_t0.elapsed().as_secs_f64() * 1e3;
            timing.total_ms += step_ms;
            let mut rec = StepRecord {
                step,
                loss: out.loss,
                lr,
                grad_norm,
                step_ms: Some(step_ms as f32),
                ..Default::default()
            };
            rec.rms.insert(pe_name.clone(), stats.rms[pe_idx]);
            rec.rms.insert(mid_name.clone(), stats.rms[mid_idx]);
            if self.cfg.probe_every > 0 && step % self.cfg.probe_every == 0 {
                let mut probes = BTreeMap::new();
                probes.insert(pe_name.clone(), TensorProbe::of(&grads[pe_idx]));
                probes.insert(mid_name.clone(), TensorProbe::of(&grads[mid_idx]));
                rec.grad_probes = probes;
                // the g²/v under-estimation ratio (the paper's spike
                // mechanism): how far the realized gradient outruns the
                // stale second moment.  Skipped on rollback steps — the
                // restored moments no longer correspond to this gradient.
                // eps matches build_optimizer's AdamWConfig.
                if !rolled_back {
                    let st = opt.export_state();
                    for (idx, name) in [(pe_idx, &pe_name), (mid_idx, &mid_name)] {
                        if let Some(r) =
                            under_estimation_ratio(&st, idx, &grads[idx], 1e-6)
                        {
                            rec.under_est.insert(name.clone(), r);
                        }
                    }
                }
                // live plane armed: publish the probe-cadence per-layer
                // gauges — g²/v for the probed tensors plus the int8
                // round-trip error and clip rate of every linear weight
                // (the signals a dynamic block-fallback policy consumes)
                if self.cfg.live.is_some() {
                    let g = trace::global();
                    for (name, r) in &rec.under_est {
                        g.gauge(&format!("train.under_est.{name}")).set(*r as f64);
                    }
                    for (idx, meta) in metas.iter().enumerate() {
                        if meta.kind == "weight" {
                            let (err, clip) =
                                crate::quant::tensorwise_quant_stats(&params[idx]);
                            g.gauge(&format!("train.quant_err.{}", meta.name))
                                .set(err as f64);
                            g.gauge(&format!("train.clip_rate.{}", meta.name))
                                .set(clip as f64);
                        }
                    }
                }
            }
            if let Some(fr) = &flight {
                let fr = &mut *fr.lock().unwrap_or_else(|e| e.into_inner());
                fr.push(FlightFrame {
                    step,
                    loss: out.loss,
                    grad_norm,
                    lr,
                    rms: rec.rms.clone(),
                    under_est: rec.under_est.clone(),
                });
                // the guard firing is the forensic moment: dump the window
                // *now*, spike frame included, before training continues
                // (a live-only recorder with no --flight-out just keeps
                // serving scrapes)
                if rolled_back && flight_dump.is_none() {
                    if let Some(p) = self.cfg.flight_path.as_ref() {
                        fr.dump_to(Path::new(p), "rollback_guard", step)?;
                        flight_dump = Some(p.clone());
                    }
                }
            }
            if verbose && (step % 10 == 0 || step == 1) {
                println!(
                    "  step {step:>5}  loss {:8.4}  acc {:4.0}%  lr {:.2e}  |g| {:8.3}",
                    out.loss,
                    100.0 * out.acc,
                    lr,
                    grad_norm
                );
            }
            sink.log(rec);
            if let Some(hooks) = &self.cfg.live {
                // gauges first, then the step counter: a scraper seeing
                // step_done == step also sees that step's scalars
                if let Some((g_step, g_loss, g_gn, g_lr)) = &live_gauges {
                    g_step.set(step as f64);
                    g_loss.set(out.loss as f64);
                    g_gn.set(grad_norm as f64);
                    g_lr.set(lr as f64);
                }
                hooks.step_done.store(step, Ordering::Relaxed);
            }
        }
        let elapsed = run_t0.elapsed().as_secs_f32();

        // join-on-exit guard: drain and error-check every background save
        // before this run reports complete (steps/s above deliberately
        // excludes the drain — that wall time never blocked a step)
        if let Some(sv) = saver.take() {
            let totals = sv.finish()?;
            snapshots += totals.snapshots;
            ckpt_bytes += totals.bytes;
            ckpt_save_secs += totals.secs;
            if let Some(dir) = &ckpt_dir {
                // the cadence prunes skipped in-flight paths; enforce the
                // final retention now that everything is committed
                ckpt::prune_snapshots(dir, self.cfg.ckpt_keep);
            }
        }

        let zero_shot_acc = if self.cfg.eval_per_concept > 0 {
            Some(self.zero_shot_eval(self.cfg.eval_per_concept))
        } else {
            None
        };

        let losses = sink.loss_trace();
        let sc = spike_cfg(h.steps);
        let loss_spike_steps = detect_loss_spikes(&losses, &sc);
        let loss_spikes = loss_spike_steps.len();
        let rms_spikes = detect_rms_spikes(&sink.rms_trace(&pe_name), &sc).len();
        let tail_loss = tail_mean_loss(&losses);
        // the guard never fired (or was off) but the post-hoc detector saw
        // a spike: still dump the recorder window for forensics
        if flight_dump.is_none() {
            if let (Some(fr), Some(&at), Some(p)) =
                (&flight, loss_spike_steps.last(), self.cfg.flight_path.as_ref())
            {
                fr.lock().unwrap_or_else(|e| e.into_inner()).dump_to(
                    Path::new(p),
                    "loss_spike",
                    self.start_step + 1 + at,
                )?;
                flight_dump = Some(p.clone());
            }
        }
        let steps_run = h.steps - self.start_step;
        // tracer overhead as a gated metric: spans recorded this run ×
        // calibrated per-span cost, relative to mean step wall time.  The
        // span counter is process-global, so concurrent runs (parallel
        // tests) make this an over-estimate; the CLI path is one run and
        // therefore accurate.
        let spans_per_step = trace::spans_recorded().saturating_sub(spans_before)
            as f64
            / steps_run.max(1) as f64;
        let mean_step_ns = timing.total_ms * 1e6 / steps_run.max(1) as f64;
        let trace_overhead_pct = if mean_step_ns > 0.0 {
            (spans_per_step * trace::calibrate_span_cost_ns(256) / mean_step_ns
                * 100.0) as f32
        } else {
            0.0
        };
        // the trainer's state now corresponds to the end of the run
        self.final_ckpt = Some(self.capture(h.steps, &params, opt.export_state()));
        self.start_step = h.steps;
        Ok(NativeRunResult {
            kind: self.cfg.encoder.kind.label(),
            optimizer: opt.name(),
            first_loss,
            final_loss: *losses.last().unwrap_or(&f32::NAN),
            tail_loss,
            final_acc,
            steps_per_sec: steps_run as f32 / elapsed.max(1e-9),
            loss_spikes,
            rms_spikes,
            diverged,
            zero_shot_acc,
            timing,
            sink,
            resumed_from,
            rollback_steps,
            snapshots,
            ckpt_bytes,
            ckpt_save_secs,
            trace_overhead_pct,
            flight_dump,
        })
    }

    /// Zero-shot-style eval through the shared nearest-class core: each
    /// concept's canonical caption is the class prompt.
    fn zero_shot_eval(&self, per_concept: usize) -> f32 {
        let n_concepts = self.data.config().n_concepts;
        let mut class_tokens = Vec::with_capacity(n_concepts * self.cfg.encoder.text_seq);
        for c in 0..n_concepts {
            class_tokens.extend(self.data.canonical_caption(c));
        }
        let class_embs = self.model.encode_texts_infer(&class_tokens);
        let eval = self.data.eval_set(per_concept);
        let images = eval.images_matrix(self.cfg.encoder.patch_dim);
        let img_embs = self.model.encode_images_infer(&images);
        nearest_class_accuracy(
            &img_embs.data,
            &class_embs.data,
            self.cfg.encoder.embed_dim,
            &eval.concepts,
        )
    }
}

/// Write `BENCH_train.json`: the native-training perf/stability artifact
/// (schema: EXPERIMENTS.md §Train).
pub fn write_bench_train_json(
    path: &str,
    cfg: &NativeTrainConfig,
    results: &[NativeRunResult],
) -> std::io::Result<()> {
    let entries: Vec<String> = results.iter().map(|r| r.to_json()).collect();
    let mut top = ObjWriter::new();
    top.field_str("bench", "train_native")
        .field_raw("config", &cfg.shared_to_json())
        .field_raw("results", &format!("[{}]", entries.join(",")));
    let doc = top.finish();
    debug_assert!(crate::util::json::parse(&doc).is_ok(), "invalid BENCH_train doc");
    std::fs::write(path, doc + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LinearKind;
    use crate::util::json::parse;

    fn tiny_cfg(kind: LinearKind, steps: u64) -> NativeTrainConfig {
        let mut cfg = NativeTrainConfig::preset(kind, steps);
        cfg.encoder.dim = 16;
        cfg.encoder.heads = 2;
        cfg.encoder.blocks = 1;
        cfg.encoder.embed_dim = 8;
        cfg.encoder.patches = 4;
        cfg.encoder.patch_dim = 12;
        cfg.encoder.text_seq = 5;
        cfg.encoder.vocab = 64;
        cfg.batch = 8;
        cfg.grad_shards = 3;
        cfg.eval_per_concept = 0;
        cfg
    }

    #[test]
    fn shard_ranges_cover_batch_exactly() {
        for (b, s) in [(8, 3), (8, 1), (8, 8), (8, 100), (1, 4), (7, 2)] {
            let ranges = shard_ranges(b, s);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges.last().unwrap().1, b);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            assert!(ranges.iter().all(|(lo, hi)| lo < hi));
        }
    }

    /// Restores `SWITCHBACK_THREADS` to "unset" even if the test panics
    /// mid-run, so a failure cannot leak the override into other tests.
    /// Holds `THREADS_ENV_TEST_LOCK` for its lifetime — env vars are
    /// process-global and several tests override this one.
    struct ThreadsEnvGuard {
        _lock: std::sync::MutexGuard<'static, ()>,
    }

    impl ThreadsEnvGuard {
        fn set(threads: &str) -> Self {
            let lock = crate::util::threads::THREADS_ENV_TEST_LOCK
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            std::env::set_var("SWITCHBACK_THREADS", threads);
            Self { _lock: lock }
        }
    }

    impl Drop for ThreadsEnvGuard {
        fn drop(&mut self) {
            std::env::remove_var("SWITCHBACK_THREADS");
        }
    }

    /// Same seed + SWITCHBACK_THREADS=1 vs N ⇒ identical first-step
    /// gradients: the shard partition and every reduction order are
    /// thread-count independent.
    #[test]
    fn first_step_grads_identical_across_thread_counts() {
        let cfg = tiny_cfg(LinearKind::SwitchBack, 1);
        let grads_with = |threads: &str| {
            let _guard = ThreadsEnvGuard::set(threads);
            let mut trainer = NativeTrainer::new(cfg.clone());
            let batch = trainer.data.next_batch(cfg.batch);
            let out = forward_backward(&trainer.model, &batch, cfg.grad_shards);
            // exercise the full param plumbing too
            let params = trainer.model.collect_params();
            trainer.model.load_params(&params);
            (out.loss, out.grads)
        };
        let (loss1, g1) = grads_with("1");
        let (loss4, g4) = grads_with("4");
        assert_eq!(loss1, loss4, "loss must be bit-identical");
        assert_eq!(g1.len(), g4.len());
        for (i, (a, b)) in g1.iter().zip(&g4).enumerate() {
            assert_eq!(a, b, "grads for tensor {i} differ across thread counts");
        }
    }

    /// Shard count is a *math-preserving* knob: loss is identical, and
    /// gradients agree to f32 summation-order noise.
    #[test]
    fn shard_count_preserves_loss_exactly() {
        let cfg = tiny_cfg(LinearKind::Standard, 1);
        let trainer = NativeTrainer::new(cfg.clone());
        let mut data = SyntheticClip::new(DataConfig {
            shifts: vec![],
            ..DataConfig::for_model(4, 12, 5, 64, cfg.hyper.seed.wrapping_add(0x5EED))
        });
        let batch = data.next_batch(cfg.batch);
        let a = forward_backward(&trainer.model, &batch, 1);
        let b = forward_backward(&trainer.model, &batch, 4);
        assert_eq!(a.loss, b.loss, "full-batch negatives regardless of shards");
        for (ga, gb) in a.grads.iter().zip(&b.grads) {
            for (&x, &y) in ga.iter().zip(gb) {
                assert!((x - y).abs() < 1e-4, "shard-order noise only: {x} vs {y}");
            }
        }
    }

    /// The 30-step smoke: loss decreases for both kinds, SwitchBack
    /// tracks Standard within tolerance (the paper's core claim on the
    /// native substrate), and telemetry/bench plumbing holds together.
    #[test]
    fn switchback_tracks_standard_over_30_steps() {
        let run = |kind| {
            let cfg = tiny_cfg(kind, 30);
            NativeTrainer::new(cfg).run(false).unwrap()
        };
        let std_res = run(LinearKind::Standard);
        let sb_res = run(LinearKind::SwitchBack);
        for r in [&std_res, &sb_res] {
            assert!(!r.diverged, "{} diverged", r.kind);
            assert!(
                r.tail_loss < r.first_loss,
                "{}: loss did not decrease ({} → {})",
                r.kind,
                r.first_loss,
                r.tail_loss
            );
            assert_eq!(r.sink.records.len(), 30);
            assert!(r.steps_per_sec > 0.0);
            assert!(r.timing.total_ms > 0.0);
        }
        // identical seeds ⇒ identical underlying f32 model; int8 noise
        // must not change where training lands within a loose band
        assert!(
            (sb_res.tail_loss - std_res.tail_loss).abs() < 0.5,
            "switchback tail {} vs standard tail {}",
            sb_res.tail_loss,
            std_res.tail_loss
        );
    }

    #[test]
    fn bench_train_json_is_parseable_and_complete() {
        let cfg = tiny_cfg(LinearKind::SwitchBack, 5);
        let mut trainer = NativeTrainer::new(cfg.clone());
        let res = trainer.run(false).unwrap();
        let path = std::env::temp_dir().join("bench_train_test.json");
        let path = path.to_str().unwrap().to_string();
        write_bench_train_json(&path, &cfg, &[res]).unwrap();
        let doc = std::fs::read_to_string(&path).unwrap();
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("train_native"));
        let config = v.get("config").unwrap();
        assert_eq!(config.get("steps").unwrap().as_usize(), Some(5));
        assert!(
            config.get("optimizer").is_none() && config.get("kind").is_none(),
            "per-run fields must live on results entries, not the shared config"
        );
        let results = v.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.get("kind").unwrap().as_str(), Some("switchback"));
        assert!(r.get("steps_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("loss_spikes").is_some());
        assert!(r.get("time_ms").unwrap().get("forward").is_some());
        // the tracer-overhead gate needs this field in every bench doc;
        // the bound is loose because parallel tests share the span counter
        let ov = r.get("trace_overhead_pct").unwrap().as_f64().unwrap();
        assert!(ov.is_finite() && ov >= 0.0, "overhead {ov}");
        let _ = std::fs::remove_file(&path);
    }

    /// The flight recorder (ISSUE 6 tentpole): a spiky rollback run dumps
    /// the last-K-steps forensic window, with the g²/v under-estimation
    /// ratio present for both probed tensors, and the bench JSON points at
    /// the dump.
    #[test]
    fn flight_recorder_dumps_on_spike_with_ratio_probes() {
        let steps = 60u64;
        let mut cfg = tiny_cfg(LinearKind::Standard, steps);
        cfg.hyper.optimizer = crate::config::OptimizerKind::Adamw;
        cfg.shifts = vec![Shift {
            at_step: 40,
            image_gain: 60.0,
            remap_concepts: true,
        }];
        cfg.rollback_on_spike = true;
        let dump_path = std::env::temp_dir().join("sb_flight_trainer_test.json");
        cfg.flight_path = Some(dump_path.to_str().unwrap().to_string());
        cfg.flight_window = 32;
        let res = NativeTrainer::new(cfg).run(false).unwrap();
        assert!(res.flight_dump.is_some(), "spiky run must write a flight dump");
        let text = std::fs::read_to_string(&dump_path).unwrap();
        let dump = crate::trace::parse_dump(&text).unwrap();
        assert_eq!(dump.window, 32);
        assert!(
            dump.trigger_kind == "rollback_guard"
                || dump.trigger_kind == "loss_spike",
            "unexpected trigger {:?}",
            dump.trigger_kind
        );
        assert!(!dump.frames.is_empty() && dump.frames.len() <= 32);
        // full-fidelity probes: both probed tensors carry the ratio
        let best = dump.frames.iter().map(|f| f.under_est.len()).max().unwrap();
        assert!(best >= 2, "expected ≥2 ratio-probed tensors, got {best}");
        assert!(res.to_json().contains("\"flight_dump\""));
        std::fs::remove_file(&dump_path).ok();
    }

    /// The live telemetry plane's trainer contract (`--telemetry-addr`):
    /// a run with `cfg.live` armed advances `step_done` to the final
    /// step, fills the shared flight recorder (scrapeable mid-run via
    /// `flight_json`), and publishes the per-layer quant-error/clip-rate
    /// gauges plus the live step scalars into the global registry.
    #[test]
    fn live_hooks_publish_steps_flight_and_quant_gauges() {
        let steps = 6u64;
        let mut cfg = tiny_cfg(LinearKind::SwitchBack, steps);
        cfg.flight_window = 4;
        let hooks = LiveHooks::new(cfg.flight_window);
        cfg.live = Some(hooks.clone());
        NativeTrainer::new(cfg).run(false).unwrap();
        assert_eq!(hooks.step_done.load(Ordering::Relaxed), steps);
        let dump = hooks.flight_json().expect("recorder must hold frames");
        let parsed = crate::trace::parse_dump(&dump).unwrap();
        assert_eq!(parsed.trigger_kind, "live_scrape");
        assert_eq!(parsed.trigger_step, steps);
        assert_eq!(parsed.frames.len(), 4, "window-capped frame count");
        let snap = crate::trace::global().snapshot();
        let has = |p: &str| snap.entries.iter().any(|(n, _)| n.starts_with(p));
        assert!(has("train.quant_err."), "per-layer quant error gauges");
        assert!(has("train.clip_rate."), "per-layer clip rate gauges");
        assert!(has("train.step") && has("train.loss"), "live step scalars");
    }

    /// The headline resume contract: train k steps + snapshot + resume to
    /// N is **bit-identical** with an uninterrupted N-step run — weights,
    /// optimizer moments and the per-step loss trace — under both
    /// SWITCHBACK_THREADS=1 and =4.
    #[test]
    fn resume_is_bit_identical_across_thread_counts() {
        let dir = std::env::temp_dir().join("sbck_resume_test");
        for threads in ["1", "4"] {
            let _guard = ThreadsEnvGuard::set(threads);
            let _ = std::fs::remove_dir_all(&dir);
            let steps = 12u64;
            let k = 5u64;
            let mut cfg = tiny_cfg(LinearKind::SwitchBack, steps);
            cfg.shifts = vec![Shift {
                at_step: 8, // a shift in the resumed segment must replay too
                image_gain: 3.0,
                remap_concepts: true,
            }];

            // uninterrupted reference run
            let mut full = NativeTrainer::new(cfg.clone());
            let full_res = full.run(false).unwrap();
            let full_ck = full.final_checkpoint().unwrap().clone();

            // interrupted run: same config, snapshots every k steps
            let mut snap_cfg = cfg.clone();
            snap_cfg.ckpt_every = k;
            snap_cfg.ckpt_dir = Some(dir.to_str().unwrap().to_string());
            snap_cfg.ckpt_keep = 10;
            let mut interrupted = NativeTrainer::new(snap_cfg);
            let int_res = interrupted.run(false).unwrap();
            assert!(int_res.snapshots >= 2, "k-cadence + final snapshot");
            let (ck, _) = ckpt::load(&ckpt::snapshot_path(&dir, k)).unwrap();
            assert_eq!(ck.step, k);

            // resume from the step-k snapshot and run to completion
            let mut resumed = NativeTrainer::new(cfg.clone());
            resumed.restore(&ck).unwrap();
            let res = resumed.run(false).unwrap();
            assert_eq!(res.resumed_from, Some(k));
            assert_eq!(res.sink.records.len(), (steps - k) as usize);

            let resumed_ck = resumed.final_checkpoint().unwrap();
            assert_eq!(
                resumed_ck.params, full_ck.params,
                "[threads={threads}] weights diverged after resume"
            );
            assert_eq!(
                resumed_ck.opt, full_ck.opt,
                "[threads={threads}] optimizer moments diverged after resume"
            );
            assert_eq!(
                resumed_ck.data, full_ck.data,
                "[threads={threads}] data cursor diverged after resume"
            );
            // loss trace of the overlapping segment matches step for step
            let full_tail: Vec<u32> = full_res.sink.loss_trace()[k as usize..]
                .iter()
                .map(|l| l.to_bits())
                .collect();
            let res_trace: Vec<u32> =
                res.sink.loss_trace().iter().map(|l| l.to_bits()).collect();
            assert_eq!(full_tail, res_trace, "[threads={threads}] loss trace diverged");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The async-save contract (ISSUE 5 tentpole): a `--ckpt-async
    /// --ckpt-shards N` run writes snapshots **bit-identical** to the
    /// synchronous single-file run's — and `--resume` from a sharded
    /// async snapshot continues bit-identically — under both
    /// SWITCHBACK_THREADS=1 and =4.
    #[test]
    fn async_sharded_snapshots_match_sync_and_resume_bit_identically() {
        let dir_sync = std::env::temp_dir().join("sbck_async_sync_a");
        let dir_async = std::env::temp_dir().join("sbck_async_sync_b");
        for threads in ["1", "4"] {
            let _guard = ThreadsEnvGuard::set(threads);
            let _ = std::fs::remove_dir_all(&dir_sync);
            let _ = std::fs::remove_dir_all(&dir_async);
            let steps = 12u64;
            let k = 5u64;
            let mut cfg = tiny_cfg(LinearKind::SwitchBack, steps);
            cfg.ckpt_every = k;
            cfg.ckpt_keep = 10;

            let mut sync_cfg = cfg.clone();
            sync_cfg.ckpt_dir = Some(dir_sync.to_str().unwrap().to_string());
            let sync_res = NativeTrainer::new(sync_cfg).run(false).unwrap();

            let mut async_cfg = cfg.clone();
            async_cfg.ckpt_dir = Some(dir_async.to_str().unwrap().to_string());
            async_cfg.ckpt_shards = 3;
            async_cfg.ckpt_async = true;
            let mut async_trainer = NativeTrainer::new(async_cfg);
            let async_res = async_trainer.run(false).unwrap();
            assert_eq!(
                async_res.snapshots, sync_res.snapshots,
                "[threads={threads}] the saver must drain every queued save"
            );
            assert!(async_res.ckpt_bytes > 0);

            // every snapshot pair decodes to the same checkpoint, and the
            // async one really is the sharded v2 layout
            for step in [k, 2 * k, steps] {
                let a = ckpt::snapshot_path(&dir_sync, step);
                let b = ckpt::snapshot_path(&dir_async, step);
                assert!(b.is_dir(), "[threads={threads}] expected a v2 dir");
                assert_eq!(ckpt::peek(&b).unwrap().version, ckpt::FORMAT_VERSION_V2);
                let (ca, _) = ckpt::load(&a).unwrap();
                let (cb, _) = ckpt::load(&b).unwrap();
                assert_eq!(ca.params, cb.params, "[threads={threads}] step {step}");
                assert_eq!(ca.opt, cb.opt, "[threads={threads}] step {step}");
                assert_eq!(ca.data, cb.data, "[threads={threads}] step {step}");
            }

            // resume from the sharded async snapshot: bit-identical tail
            let (ck, _) = ckpt::load(&ckpt::snapshot_path(&dir_async, k)).unwrap();
            let mut resumed = NativeTrainer::new(cfg.clone());
            resumed.restore(&ck).unwrap();
            let _ = resumed.run(false).unwrap();
            let full_ck = async_trainer.final_checkpoint().unwrap();
            let resumed_ck = resumed.final_checkpoint().unwrap();
            assert_eq!(
                resumed_ck.params, full_ck.params,
                "[threads={threads}] weights diverged resuming from a \
                 sharded async snapshot"
            );
            assert_eq!(resumed_ck.opt, full_ck.opt, "[threads={threads}]");
            assert_eq!(resumed_ck.data, full_ck.data, "[threads={threads}]");
        }
        std::fs::remove_dir_all(&dir_sync).ok();
        std::fs::remove_dir_all(&dir_async).ok();
    }

    /// Restore fails closed on mismatched hyper/shape/schedule.
    #[test]
    fn restore_rejects_incompatible_checkpoints() {
        let cfg = tiny_cfg(LinearKind::Standard, 10);
        let mut a = NativeTrainer::new(cfg.clone());
        let _ = a.run(false).unwrap();
        let done = a.final_checkpoint().unwrap().clone();
        // finished checkpoint: nothing to resume
        let mut b = NativeTrainer::new(cfg.clone());
        assert!(b.restore(&done).is_err());
        // mid-run checkpoint against a different lr: rejected
        let mut ck = done.clone();
        ck.step = 5;
        let mut lr_cfg = cfg.clone();
        lr_cfg.hyper.lr *= 2.0;
        let mut c = NativeTrainer::new(lr_cfg);
        let err = c.restore(&ck).unwrap_err().to_string();
        assert!(err.contains("hyper"), "{err}");
        // different model seed: rejected
        let mut seed_cfg = cfg.clone();
        seed_cfg.encoder.seed = 43;
        let mut d = NativeTrainer::new(seed_cfg);
        assert!(d.restore(&ck).is_err());
        // different shift schedule: rejected
        let mut shift_cfg = cfg;
        shift_cfg.shifts =
            vec![Shift { at_step: 3, image_gain: 2.0, remap_concepts: false }];
        let mut e = NativeTrainer::new(shift_cfg);
        assert!(e.restore(&ck).is_err());
    }

    /// The spike-rollback guard: under an aggressive distribution shift
    /// with plain AdamW, the guard fires, reverts to the snapshot, and the
    /// run completes without diverging.
    #[test]
    fn rollback_guard_fires_on_shift_spike_and_recovers() {
        let steps = 60u64;
        let mut cfg = tiny_cfg(LinearKind::Standard, steps);
        cfg.hyper.optimizer = crate::config::OptimizerKind::Adamw;
        cfg.shifts = vec![Shift {
            at_step: 40, // well past burn-in (spike_cfg(60) → 20)
            image_gain: 60.0,
            remap_concepts: true,
        }];
        cfg.rollback_on_spike = true;
        let mut trainer = NativeTrainer::new(cfg);
        let res = trainer.run(false).unwrap();
        assert!(
            !res.rollback_steps.is_empty(),
            "guard never fired under a 60× input-gain shift"
        );
        assert!(
            res.rollback_steps.iter().any(|&s| s > 40),
            "at least one rollback must follow the shift: {:?}",
            res.rollback_steps
        );
        assert!(!res.diverged, "rolled-back spikes must not count as divergence");
        assert!(res.final_loss.is_finite());
    }

    /// RollbackGuard unit behavior: confirmation window, cooldown,
    /// non-finite losses, burn-in.
    #[test]
    fn rollback_guard_confirmation_and_cooldown() {
        let cfg = SpikeConfig { burn_in: 5, stat_window: 50, ..Default::default() };
        let mut g = RollbackGuard::new(cfg.clone(), 3 * DEDUP_WINDOW);
        for t in 1..=20u64 {
            assert!(!g.observe(t, 1.0 + (t % 3) as f32 * 0.01), "baseline fired");
        }
        // one deviation arms the guard, the confirming one triggers it
        assert!(!g.observe(21, 9.0));
        assert!(g.observe(22, 9.0), "second deviation within window must fire");
        // cooldown: continued deviations right after do not re-trigger
        assert!(!g.observe(23, 9.0));
        assert!(!g.observe(24, 9.0));

        // a lone deviation (no confirmation within 10) never fires, arms
        // the guard only for the confirmation window, then disarms
        let mut g = RollbackGuard::new(cfg.clone(), 3 * DEDUP_WINDOW);
        for t in 1..=20u64 {
            g.observe(t, 1.0 + (t % 3) as f32 * 0.01);
        }
        assert!(!g.observe(21, 9.0));
        assert!(g.armed(), "pending deviation must block snapshot refresh");
        for t in 22..=40u64 {
            assert!(!g.observe(t, 1.0), "stale deviation fired at {t}");
        }
        assert!(!g.armed(), "stale deviation must disarm the guard");

        // NaN loss counts as a deviation but never enters the baseline:
        // the window stats stay finite and later spikes are still caught
        let mut g = RollbackGuard::new(cfg, 3 * DEDUP_WINDOW);
        for t in 1..=10u64 {
            g.observe(t, 1.0 + (t % 3) as f32 * 0.01);
        }
        assert!(!g.observe(11, f32::NAN));
        assert!(g.observe(12, f32::NAN));
        for t in 13..=42u64 {
            g.observe(t, 1.0 + (t % 3) as f32 * 0.01); // past the cooldown
        }
        assert!(!g.observe(43, 9.0), "first deviation only arms");
        assert!(g.observe(44, 9.0), "NaN must not have blinded the window");
    }

    /// The guard knobs are real: a huge `--spike-sigma` silences the
    /// guard on the same shift that fires it at the default, and a short
    /// `--spike-cooldown` re-arms sooner than the default 30 steps.
    #[test]
    fn spike_sigma_and_cooldown_are_tunable() {
        let steps = 60u64;
        let mut cfg = tiny_cfg(LinearKind::Standard, steps);
        cfg.hyper.optimizer = crate::config::OptimizerKind::Adamw;
        cfg.shifts = vec![Shift {
            at_step: 40,
            image_gain: 60.0,
            remap_concepts: true,
        }];
        cfg.rollback_on_spike = true;
        cfg.spike_sigma = 1e6; // nothing is a 1e6σ deviation
        let res = NativeTrainer::new(cfg).run(false).unwrap();
        assert!(
            res.rollback_steps.is_empty(),
            "a 1e6σ threshold must silence the guard, fired at {:?}",
            res.rollback_steps
        );

        // cooldown: default 30 suppresses a second fire at distance 12;
        // cooldown 5 lets it through
        for (cooldown, expect_second) in [(3 * DEDUP_WINDOW, false), (5u64, true)] {
            let sc = SpikeConfig { burn_in: 5, stat_window: 50, ..Default::default() };
            let mut g = RollbackGuard::new(sc, cooldown);
            for t in 1..=20u64 {
                g.observe(t, 1.0 + (t % 3) as f32 * 0.01);
            }
            assert!(!g.observe(21, 9.0));
            assert!(g.observe(22, 9.0), "first confirmed spike fires");
            // the 9.0s entered the trailing baseline, so the second burst
            // must clear the inflated mean+σ threshold: use 30.0
            assert!(!g.observe(32, 30.0), "arming deviation only");
            assert_eq!(
                g.observe(33, 30.0),
                expect_second,
                "cooldown {cooldown}: second spike at distance 11"
            );
        }
    }

    /// Zero-shot eval runs and returns a sane range after a short run.
    #[test]
    fn zero_shot_eval_is_in_range() {
        let mut cfg = tiny_cfg(LinearKind::Standard, 8);
        cfg.eval_per_concept = 1;
        let mut trainer = NativeTrainer::new(cfg);
        let res = trainer.run(false).unwrap();
        let acc = res.zero_shot_acc.unwrap();
        assert!((0.0..=1.0).contains(&acc), "acc {acc}");
    }
}
