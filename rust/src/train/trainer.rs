//! The native training loop — the PJRT-free end-to-end path.
//!
//! Per step:
//! 1. synthesize the next batch ([`crate::data`], honouring the shift
//!    schedule — the same spike trigger the PJRT path uses),
//! 2. forward both towers over `grad_shards` fixed batch shards on
//!    [`crate::util::threads::par_map`] workers,
//! 3. compute the symmetric InfoNCE loss *globally* (full-batch in-batch
//!    negatives — sharding never changes the math),
//! 4. backward each shard in parallel, then sum shard gradients in shard
//!    order,
//! 5. optionally clip the global gradient norm,
//! 6. step the optimizer (AdamW / StableAdamW / Lion via
//!    `coordinator::common::build_optimizer`) with the warmup+cosine LR,
//!    collecting per-tensor `RMS_t`,
//! 7. log to the metrics sink (JSONL) with per-step RMS probes.
//!
//! **Determinism**: the shard partition depends only on `batch` and
//! `grad_shards` (never on the worker count), every per-element reduction
//! in the substrate runs sequentially inside one worker, and shard
//! gradients are summed in shard order — so a step's gradients are
//! bit-identical under any `SWITCHBACK_THREADS` setting (tested below).

use super::loss::clip_contrastive;
use super::model::ClipTrainModel;
use crate::config::TrainHyper;
use crate::coordinator::common::{build_optimizer, spike_cfg, tail_mean_loss};
use crate::coordinator::eval::nearest_class_accuracy;
use crate::data::{Batch, DataConfig, Shift, SyntheticClip};
use crate::optim::clip_global_norm;
use crate::optim::schedules::LrSchedule;
use crate::serve::EncoderConfig;
use crate::telemetry::{
    detect_loss_spikes, detect_rms_spikes, MetricsSink, StepRecord, TensorProbe,
};
use crate::tensor::Matrix;
use crate::util::json::ObjWriter;
use crate::util::threads::par_map;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

/// One native training run's knobs.
#[derive(Debug, Clone)]
pub struct NativeTrainConfig {
    /// optimizer/schedule hyperparameters (shared with the PJRT path)
    pub hyper: TrainHyper,
    /// model shape + precision kind (shared with the serving encoder)
    pub encoder: EncoderConfig,
    pub batch: usize,
    /// fixed data-parallel shard count for gradient accumulation (the
    /// partition is thread-count independent; workers come from
    /// `SWITCHBACK_THREADS`)
    pub grad_shards: usize,
    /// scheduled distribution shifts (the spike trigger)
    pub shifts: Vec<Shift>,
    /// log grad probes every N steps (0 = never)
    pub probe_every: u64,
    /// JSONL metrics path (None = in-memory only)
    pub metrics_path: Option<String>,
    /// examples per concept for the final zero-shot eval (0 = skip)
    pub eval_per_concept: usize,
}

impl NativeTrainConfig {
    /// Small-model defaults: big enough that SwitchBack's int8 GEMMs do
    /// real work, small enough that a 50-step smoke runs in seconds.
    pub fn preset(kind: crate::nn::LinearKind, steps: u64) -> Self {
        let hyper = TrainHyper {
            lr: 1e-3,
            weight_decay: 0.1,
            seed: 42,
            ..TrainHyper::preset(steps)
        };
        Self {
            hyper,
            encoder: EncoderConfig {
                kind,
                dim: 64,
                heads: 4,
                blocks: 2,
                embed_dim: 32,
                patches: 8,
                patch_dim: 32,
                text_seq: 8,
                vocab: 256,
                seed: 42,
            },
            batch: 32,
            grad_shards: 4,
            shifts: vec![],
            probe_every: 1,
            metrics_path: None,
            eval_per_concept: 2,
        }
    }

    /// JSON echo of one run's config (per-run logs: includes this run's
    /// kind and optimizer).
    pub fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_str("kind", self.encoder.kind.label());
        self.hyper.write_json(&mut w);
        self.write_shape_json(&mut w);
        w.finish()
    }

    /// JSON echo of the run-matrix-invariant slice (BENCH_train.json's
    /// `config` block): shape + schedule only.  Kind and optimizer vary
    /// across the matrix and live on each `results` entry instead.
    pub fn shared_to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_u64("steps", self.hyper.steps)
            .field_u64("warmup", self.hyper.warmup)
            .field_f32("lr", self.hyper.lr)
            .field_f32("weight_decay", self.hyper.weight_decay)
            .field_f32("beta1", self.hyper.beta1)
            .field_f32("beta2", self.hyper.beta2)
            .field_u64("seed", self.hyper.seed);
        if let Some(l) = self.hyper.beta2_lambda {
            w.field_f32("beta2_lambda", l);
        }
        if let Some(c) = self.hyper.grad_clip {
            w.field_f32("grad_clip", c);
        }
        self.write_shape_json(&mut w);
        w.finish()
    }

    fn write_shape_json(&self, w: &mut ObjWriter) {
        w.field_u64("batch", self.batch as u64)
            .field_u64("grad_shards", self.grad_shards as u64)
            .field_u64("dim", self.encoder.dim as u64)
            .field_u64("heads", self.encoder.heads as u64)
            .field_u64("blocks", self.encoder.blocks as u64)
            .field_u64("embed_dim", self.encoder.embed_dim as u64)
            .field_u64("patches", self.encoder.patches as u64)
            .field_u64("patch_dim", self.encoder.patch_dim as u64)
            .field_u64("text_seq", self.encoder.text_seq as u64)
            .field_u64("vocab", self.encoder.vocab as u64);
        if !self.shifts.is_empty() {
            w.field_u64("n_shifts", self.shifts.len() as u64);
        }
    }
}

/// Output of one fused forward + loss + backward pass.
pub struct StepOutput {
    pub loss: f32,
    /// in-batch image→text retrieval accuracy
    pub acc: f32,
    /// flat per-tensor gradients aligned with the model's param layout
    pub grads: Vec<Vec<f32>>,
    pub forward_ms: f64,
    pub loss_ms: f64,
    pub backward_ms: f64,
}

/// Contiguous shard ranges over `batch` examples — a pure function of
/// `(batch, shards)`, never of the worker count (the determinism anchor).
fn shard_ranges(batch: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.clamp(1, batch.max(1));
    let per = batch.div_ceil(shards);
    (0..shards)
        .map(|s| (s * per, ((s + 1) * per).min(batch)))
        .filter(|(lo, hi)| lo < hi)
        .collect()
}

/// One training step's compute: sharded forward, global contrastive loss,
/// sharded backward, ordered gradient accumulation.
pub fn forward_backward(
    model: &ClipTrainModel,
    batch: &Batch,
    grad_shards: usize,
) -> StepOutput {
    let c = &model.cfg;
    let n = batch.len();
    assert!(n > 0, "empty batch");
    let ranges = shard_ranges(n, grad_shards);
    let img_row = c.patches * c.patch_dim;
    assert_eq!(batch.images.len(), n * img_row, "image payload shape");

    // 1) sharded forward (shard slices come straight from the batch — no
    //    full-batch intermediate copy on the hot path)
    let t0 = Instant::now();
    let caches = par_map(ranges.len(), |s| {
        let (lo, hi) = ranges[s];
        let rows = (hi - lo) * c.patches;
        let sub = Matrix::from_vec(
            rows,
            c.patch_dim,
            batch.images[lo * img_row..hi * img_row].to_vec(),
        );
        let toks = &batch.tokens[lo * c.text_seq..hi * c.text_seq];
        model.forward(&sub, toks)
    });
    let forward_ms = t0.elapsed().as_secs_f64() * 1e3;

    // 2) global loss over the assembled full-batch embeddings
    let t1 = Instant::now();
    let e = c.embed_dim;
    let mut img_z = Matrix::zeros(n, e);
    let mut txt_z = Matrix::zeros(n, e);
    for (cache, &(lo, hi)) in caches.iter().zip(&ranges) {
        img_z.data[lo * e..hi * e].copy_from_slice(&cache.img_z().data);
        txt_z.data[lo * e..hi * e].copy_from_slice(&cache.txt_z().data);
    }
    let out = clip_contrastive(&img_z, &txt_z, model.log_scale);
    let loss_ms = t1.elapsed().as_secs_f64() * 1e3;

    // 3) sharded backward + ordered accumulation
    let t2 = Instant::now();
    let shard_grads = par_map(ranges.len(), |s| {
        let (lo, hi) = ranges[s];
        let rows = hi - lo;
        let d_img = Matrix::from_vec(rows, e, out.d_img.data[lo * e..hi * e].to_vec());
        let d_txt = Matrix::from_vec(rows, e, out.d_txt.data[lo * e..hi * e].to_vec());
        model.backward(&caches[s], &d_img, &d_txt)
    });
    let mut grads: Vec<Vec<f32>> = shard_grads
        .into_iter()
        .reduce(|mut acc, shard| {
            for (a, s) in acc.iter_mut().zip(&shard) {
                for (av, &sv) in a.iter_mut().zip(s) {
                    *av += sv;
                }
            }
            acc
        })
        .expect("at least one shard");
    let last = grads.len() - 1;
    grads[last][0] = out.d_log_scale; // global, not per-shard
    let backward_ms = t2.elapsed().as_secs_f64() * 1e3;

    StepOutput {
        loss: out.loss,
        acc: out.acc,
        grads,
        forward_ms,
        loss_ms,
        backward_ms,
    }
}

/// Accumulated wall-time breakdown over a run (milliseconds).
#[derive(Debug, Clone, Default)]
pub struct StepTiming {
    pub data_ms: f64,
    pub forward_ms: f64,
    pub loss_ms: f64,
    pub backward_ms: f64,
    pub optim_ms: f64,
    pub total_ms: f64,
}

impl StepTiming {
    fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_f32("data", self.data_ms as f32)
            .field_f32("forward", self.forward_ms as f32)
            .field_f32("loss", self.loss_ms as f32)
            .field_f32("backward", self.backward_ms as f32)
            .field_f32("optim", self.optim_ms as f32)
            .field_f32("total", self.total_ms as f32);
        w.finish()
    }
}

/// Outcome of one native run.
pub struct NativeRunResult {
    pub kind: &'static str,
    pub optimizer: &'static str,
    pub first_loss: f32,
    pub final_loss: f32,
    /// mean loss over the last 10% of steps (robust curve endpoint)
    pub tail_loss: f32,
    /// in-batch retrieval accuracy at the final step
    pub final_acc: f32,
    pub steps_per_sec: f32,
    pub loss_spikes: usize,
    pub rms_spikes: usize,
    pub diverged: bool,
    pub zero_shot_acc: Option<f32>,
    pub timing: StepTiming,
    pub sink: MetricsSink,
}

impl NativeRunResult {
    pub fn print(&self) {
        println!(
            "[{:<12}/{:<13}] loss {:.4} → {:.4} (tail {:.4})  acc {:4.0}%  \
             {:5.1} steps/s  spikes {}/{}{}",
            self.kind,
            self.optimizer,
            self.first_loss,
            self.final_loss,
            self.tail_loss,
            100.0 * self.final_acc,
            self.steps_per_sec,
            self.loss_spikes,
            self.rms_spikes,
            if self.diverged { "  [DIVERGED]" } else { "" },
        );
        if let Some(acc) = self.zero_shot_acc {
            println!("               zero-shot acc {:.1}%", 100.0 * acc);
        }
    }

    fn to_json(&self) -> String {
        let mut w = ObjWriter::new();
        w.field_str("kind", self.kind)
            .field_str("optimizer", self.optimizer)
            .field_f32("first_loss", self.first_loss)
            .field_f32("final_loss", self.final_loss)
            .field_f32("tail_loss", self.tail_loss)
            .field_f32("final_acc", self.final_acc)
            .field_f32("steps_per_sec", self.steps_per_sec)
            .field_u64("loss_spikes", self.loss_spikes as u64)
            .field_u64("rms_spikes", self.rms_spikes as u64)
            .field_bool("diverged", self.diverged)
            .field_raw("time_ms", &self.timing.to_json());
        if let Some(acc) = self.zero_shot_acc {
            w.field_f32("zero_shot_acc", acc);
        }
        w.finish()
    }
}

/// The native trainer: owns the model, the data stream and the config.
pub struct NativeTrainer {
    cfg: NativeTrainConfig,
    model: ClipTrainModel,
    data: SyntheticClip,
}

impl NativeTrainer {
    pub fn new(cfg: NativeTrainConfig) -> Self {
        let e = &cfg.encoder;
        let data = SyntheticClip::new(DataConfig {
            shifts: cfg.shifts.clone(),
            ..DataConfig::for_model(
                e.patches,
                e.patch_dim,
                e.text_seq,
                e.vocab,
                cfg.hyper.seed.wrapping_add(0x5EED),
            )
        });
        let model = ClipTrainModel::new(e.clone());
        Self { cfg, model, data }
    }

    pub fn model(&self) -> &ClipTrainModel {
        &self.model
    }

    /// Run the configured number of steps.
    pub fn run(&mut self, verbose: bool) -> Result<NativeRunResult> {
        let h = self.cfg.hyper.clone();
        let metas = self.model.param_metas();
        let mut params = self.model.collect_params();
        let sizes: Vec<usize> = params.iter().map(|p| p.len()).collect();
        let mut opt = build_optimizer(&h, &metas, &sizes);
        let schedule = LrSchedule::new(h.lr, h.warmup, h.steps);
        let (pe_idx, mid_idx) = self.model.probe_indices();
        let pe_name = metas[pe_idx].name.clone();
        let mid_name = metas[mid_idx].name.clone();

        let mut sink = match &self.cfg.metrics_path {
            Some(p) => MetricsSink::to_file(Path::new(p))?,
            None => MetricsSink::memory(),
        };
        let mut timing = StepTiming::default();
        let mut first_loss = f32::NAN;
        let mut final_acc = 0.0f32;
        let mut diverged = false;
        let run_t0 = Instant::now();

        for step in 1..=h.steps {
            let step_t0 = Instant::now();
            let batch = self.data.next_batch(self.cfg.batch);
            timing.data_ms += step_t0.elapsed().as_secs_f64() * 1e3;

            let out = forward_backward(&self.model, &batch, self.cfg.grad_shards);
            timing.forward_ms += out.forward_ms;
            timing.loss_ms += out.loss_ms;
            timing.backward_ms += out.backward_ms;
            if step == 1 {
                first_loss = out.loss;
            }
            final_acc = out.acc;
            if !out.loss.is_finite() || out.loss > 50.0 {
                diverged = true;
            }

            let mut grads = out.grads;
            let grad_norm = {
                let mut ss = 0.0f64;
                for g in &grads {
                    for &v in g {
                        if v.is_finite() {
                            ss += (v as f64) * (v as f64);
                        }
                    }
                }
                ss.sqrt() as f32
            };
            if let Some(max_norm) = h.grad_clip {
                clip_global_norm(&mut grads, max_norm);
            }

            let t_opt = Instant::now();
            let lr = schedule.at(step);
            let stats = opt.step(&mut params, &grads, lr, None);
            self.model.load_params(&params);
            timing.optim_ms += t_opt.elapsed().as_secs_f64() * 1e3;

            let step_ms = step_t0.elapsed().as_secs_f64() * 1e3;
            timing.total_ms += step_ms;
            let mut rec = StepRecord {
                step,
                loss: out.loss,
                lr,
                grad_norm,
                step_ms: Some(step_ms as f32),
                ..Default::default()
            };
            rec.rms.insert(pe_name.clone(), stats.rms[pe_idx]);
            rec.rms.insert(mid_name.clone(), stats.rms[mid_idx]);
            if self.cfg.probe_every > 0 && step % self.cfg.probe_every == 0 {
                let mut probes = BTreeMap::new();
                probes.insert(pe_name.clone(), TensorProbe::of(&grads[pe_idx]));
                probes.insert(mid_name.clone(), TensorProbe::of(&grads[mid_idx]));
                rec.grad_probes = probes;
            }
            if verbose && (step % 10 == 0 || step == 1) {
                println!(
                    "  step {step:>5}  loss {:8.4}  acc {:4.0}%  lr {:.2e}  |g| {:8.3}",
                    out.loss,
                    100.0 * out.acc,
                    lr,
                    grad_norm
                );
            }
            sink.log(rec);
        }
        let elapsed = run_t0.elapsed().as_secs_f32();

        let zero_shot_acc = if self.cfg.eval_per_concept > 0 {
            Some(self.zero_shot_eval(self.cfg.eval_per_concept))
        } else {
            None
        };

        let losses = sink.loss_trace();
        let sc = spike_cfg(h.steps);
        let loss_spikes = detect_loss_spikes(&losses, &sc).len();
        let rms_spikes = detect_rms_spikes(&sink.rms_trace(&pe_name), &sc).len();
        let tail_loss = tail_mean_loss(&losses);
        Ok(NativeRunResult {
            kind: self.cfg.encoder.kind.label(),
            optimizer: opt.name(),
            first_loss,
            final_loss: *losses.last().unwrap_or(&f32::NAN),
            tail_loss,
            final_acc,
            steps_per_sec: h.steps as f32 / elapsed.max(1e-9),
            loss_spikes,
            rms_spikes,
            diverged,
            zero_shot_acc,
            timing,
            sink,
        })
    }

    /// Zero-shot-style eval through the shared nearest-class core: each
    /// concept's canonical caption is the class prompt.
    fn zero_shot_eval(&self, per_concept: usize) -> f32 {
        let n_concepts = self.data.config().n_concepts;
        let mut class_tokens = Vec::with_capacity(n_concepts * self.cfg.encoder.text_seq);
        for c in 0..n_concepts {
            class_tokens.extend(self.data.canonical_caption(c));
        }
        let class_embs = self.model.encode_texts_infer(&class_tokens);
        let eval = self.data.eval_set(per_concept);
        let images = eval.images_matrix(self.cfg.encoder.patch_dim);
        let img_embs = self.model.encode_images_infer(&images);
        nearest_class_accuracy(
            &img_embs.data,
            &class_embs.data,
            self.cfg.encoder.embed_dim,
            &eval.concepts,
        )
    }
}

/// Write `BENCH_train.json`: the native-training perf/stability artifact
/// (schema: EXPERIMENTS.md §Train).
pub fn write_bench_train_json(
    path: &str,
    cfg: &NativeTrainConfig,
    results: &[NativeRunResult],
) -> std::io::Result<()> {
    let entries: Vec<String> = results.iter().map(|r| r.to_json()).collect();
    let mut top = ObjWriter::new();
    top.field_str("bench", "train_native")
        .field_raw("config", &cfg.shared_to_json())
        .field_raw("results", &format!("[{}]", entries.join(",")));
    let doc = top.finish();
    debug_assert!(crate::util::json::parse(&doc).is_ok(), "invalid BENCH_train doc");
    std::fs::write(path, doc + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LinearKind;
    use crate::util::json::parse;

    fn tiny_cfg(kind: LinearKind, steps: u64) -> NativeTrainConfig {
        let mut cfg = NativeTrainConfig::preset(kind, steps);
        cfg.encoder.dim = 16;
        cfg.encoder.heads = 2;
        cfg.encoder.blocks = 1;
        cfg.encoder.embed_dim = 8;
        cfg.encoder.patches = 4;
        cfg.encoder.patch_dim = 12;
        cfg.encoder.text_seq = 5;
        cfg.encoder.vocab = 64;
        cfg.batch = 8;
        cfg.grad_shards = 3;
        cfg.eval_per_concept = 0;
        cfg
    }

    #[test]
    fn shard_ranges_cover_batch_exactly() {
        for (b, s) in [(8, 3), (8, 1), (8, 8), (8, 100), (1, 4), (7, 2)] {
            let ranges = shard_ranges(b, s);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges.last().unwrap().1, b);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            assert!(ranges.iter().all(|(lo, hi)| lo < hi));
        }
    }

    /// Restores `SWITCHBACK_THREADS` to "unset" even if the test panics
    /// mid-run, so a failure cannot leak the override into other tests.
    /// (No other test writes this var; all in-process readers go through
    /// `std::env`, which serializes access internally.)
    struct ThreadsEnvGuard;

    impl ThreadsEnvGuard {
        fn set(threads: &str) -> Self {
            std::env::set_var("SWITCHBACK_THREADS", threads);
            Self
        }
    }

    impl Drop for ThreadsEnvGuard {
        fn drop(&mut self) {
            std::env::remove_var("SWITCHBACK_THREADS");
        }
    }

    /// Same seed + SWITCHBACK_THREADS=1 vs N ⇒ identical first-step
    /// gradients: the shard partition and every reduction order are
    /// thread-count independent.
    #[test]
    fn first_step_grads_identical_across_thread_counts() {
        let cfg = tiny_cfg(LinearKind::SwitchBack, 1);
        let grads_with = |threads: &str| {
            let _guard = ThreadsEnvGuard::set(threads);
            let mut trainer = NativeTrainer::new(cfg.clone());
            let batch = trainer.data.next_batch(cfg.batch);
            let out = forward_backward(&trainer.model, &batch, cfg.grad_shards);
            // exercise the full param plumbing too
            let params = trainer.model.collect_params();
            trainer.model.load_params(&params);
            (out.loss, out.grads)
        };
        let (loss1, g1) = grads_with("1");
        let (loss4, g4) = grads_with("4");
        assert_eq!(loss1, loss4, "loss must be bit-identical");
        assert_eq!(g1.len(), g4.len());
        for (i, (a, b)) in g1.iter().zip(&g4).enumerate() {
            assert_eq!(a, b, "grads for tensor {i} differ across thread counts");
        }
    }

    /// Shard count is a *math-preserving* knob: loss is identical, and
    /// gradients agree to f32 summation-order noise.
    #[test]
    fn shard_count_preserves_loss_exactly() {
        let cfg = tiny_cfg(LinearKind::Standard, 1);
        let trainer = NativeTrainer::new(cfg.clone());
        let mut data = SyntheticClip::new(DataConfig {
            shifts: vec![],
            ..DataConfig::for_model(4, 12, 5, 64, cfg.hyper.seed.wrapping_add(0x5EED))
        });
        let batch = data.next_batch(cfg.batch);
        let a = forward_backward(&trainer.model, &batch, 1);
        let b = forward_backward(&trainer.model, &batch, 4);
        assert_eq!(a.loss, b.loss, "full-batch negatives regardless of shards");
        for (ga, gb) in a.grads.iter().zip(&b.grads) {
            for (&x, &y) in ga.iter().zip(gb) {
                assert!((x - y).abs() < 1e-4, "shard-order noise only: {x} vs {y}");
            }
        }
    }

    /// The 30-step smoke: loss decreases for both kinds, SwitchBack
    /// tracks Standard within tolerance (the paper's core claim on the
    /// native substrate), and telemetry/bench plumbing holds together.
    #[test]
    fn switchback_tracks_standard_over_30_steps() {
        let run = |kind| {
            let cfg = tiny_cfg(kind, 30);
            NativeTrainer::new(cfg).run(false).unwrap()
        };
        let std_res = run(LinearKind::Standard);
        let sb_res = run(LinearKind::SwitchBack);
        for r in [&std_res, &sb_res] {
            assert!(!r.diverged, "{} diverged", r.kind);
            assert!(
                r.tail_loss < r.first_loss,
                "{}: loss did not decrease ({} → {})",
                r.kind,
                r.first_loss,
                r.tail_loss
            );
            assert_eq!(r.sink.records.len(), 30);
            assert!(r.steps_per_sec > 0.0);
            assert!(r.timing.total_ms > 0.0);
        }
        // identical seeds ⇒ identical underlying f32 model; int8 noise
        // must not change where training lands within a loose band
        assert!(
            (sb_res.tail_loss - std_res.tail_loss).abs() < 0.5,
            "switchback tail {} vs standard tail {}",
            sb_res.tail_loss,
            std_res.tail_loss
        );
    }

    #[test]
    fn bench_train_json_is_parseable_and_complete() {
        let cfg = tiny_cfg(LinearKind::SwitchBack, 5);
        let mut trainer = NativeTrainer::new(cfg.clone());
        let res = trainer.run(false).unwrap();
        let path = std::env::temp_dir().join("bench_train_test.json");
        let path = path.to_str().unwrap().to_string();
        write_bench_train_json(&path, &cfg, &[res]).unwrap();
        let doc = std::fs::read_to_string(&path).unwrap();
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("train_native"));
        let config = v.get("config").unwrap();
        assert_eq!(config.get("steps").unwrap().as_usize(), Some(5));
        assert!(
            config.get("optimizer").is_none() && config.get("kind").is_none(),
            "per-run fields must live on results entries, not the shared config"
        );
        let results = v.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.get("kind").unwrap().as_str(), Some("switchback"));
        assert!(r.get("steps_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("loss_spikes").is_some());
        assert!(r.get("time_ms").unwrap().get("forward").is_some());
        let _ = std::fs::remove_file(&path);
    }

    /// Zero-shot eval runs and returns a sane range after a short run.
    #[test]
    fn zero_shot_eval_is_in_range() {
        let mut cfg = tiny_cfg(LinearKind::Standard, 8);
        cfg.eval_per_concept = 1;
        let mut trainer = NativeTrainer::new(cfg);
        let res = trainer.run(false).unwrap();
        let acc = res.zero_shot_acc.unwrap();
        assert!((0.0..=1.0).contains(&acc), "acc {acc}");
    }
}
