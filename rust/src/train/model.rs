//! The trainable dual-tower CLIP model on the native substrate.
//!
//! Same architecture — and the *same seeding* — as the serving encoder
//! (`serve::encoder::ClipEncoder`): input projection / token embedding →
//! N pre-norm [`TransformerBlock`]s → mean-pool → output projection → L2
//! normalize, with every projection routed through the precision-pluggable
//! [`crate::nn::Linear`].  A freshly constructed `ClipTrainModel` and a
//! `ClipEncoder` built from the same [`EncoderConfig`] encode identically
//! (bit-for-bit; tested below), so a trained parameter vector drops
//! straight into the serving engine's world.
//!
//! Trainable parameters are the projections, the token-embedding table
//! and the logit scale; layernorm affine params stay at identity like the
//! speed benches (`nn::block` does not emit LN grads — the projections
//! dominate, and this keeps the backward exactly the Fig 4/13 workload).

use crate::nn::{
    l2_normalize_rows, mean_pool_rows, BlockCache, Linear, LinearCache,
    TransformerBlock,
};
use crate::optim::ParamMeta;
use crate::serve::EncoderConfig;
use crate::tensor::{Matrix, Rng};

/// Canonical per-block projection names (order of
/// [`TransformerBlock::projections`]).
pub const PROJ_NAMES: [&str; 6] = ["wq", "wk", "wv", "wo", "w1", "w2"];

/// One tower's forward bookkeeping.
struct TowerCache {
    blocks: Vec<BlockCache>,
    out: LinearCache,
    /// pre-normalization row norms of the projected embeddings
    norms: Vec<f32>,
    /// normalized embeddings `[n, embed_dim]` (the tower output)
    z: Matrix,
}

/// Everything one forward pass saves for the backward pass.
pub struct FwdCache {
    img_pe: LinearCache,
    img_tower: TowerCache,
    /// vocab-wrapped token ids, one per text-input row
    txt_tokens: Vec<usize>,
    txt_tower: TowerCache,
}

impl FwdCache {
    /// Normalized image embeddings `[n, embed_dim]`.
    pub fn img_z(&self) -> &Matrix {
        &self.img_tower.z
    }

    /// Normalized text embeddings `[n, embed_dim]`.
    pub fn txt_z(&self) -> &Matrix {
        &self.txt_tower.z
    }
}

/// The trainable dual-tower CLIP model.
pub struct ClipTrainModel {
    pub cfg: EncoderConfig,
    pub patch_embed: Linear,
    /// `[vocab, dim]` token-embedding table (lookup, not a matmul)
    pub tok_embed: Matrix,
    pub image_blocks: Vec<TransformerBlock>,
    pub image_out: Linear,
    pub text_blocks: Vec<TransformerBlock>,
    pub text_out: Linear,
    /// learnable log temperature (CLIP's logit scale)
    pub log_scale: f32,
}

impl ClipTrainModel {
    /// Deterministic init from `cfg.seed`, drawing the RNG streams in the
    /// exact order `serve::ClipEncoder::new` does, so both construct the
    /// same underlying f32 model (kind-independent, like serving).
    pub fn new(cfg: EncoderConfig) -> Self {
        assert_eq!(cfg.dim % cfg.heads, 0, "dim must divide by heads");
        let mut rng = Rng::seed(cfg.seed);
        let patch_embed = Linear::new(cfg.dim, cfg.patch_dim, cfg.kind, &mut rng);
        let tok_embed = Matrix::randn(cfg.vocab, cfg.dim, 0.02, &mut rng);
        let build_tower = |seq: usize, rng: &mut Rng| {
            let blocks: Vec<TransformerBlock> = (0..cfg.blocks)
                .map(|_| TransformerBlock::new(cfg.dim, cfg.heads, seq, cfg.kind, rng))
                .collect();
            let out = Linear::new(cfg.embed_dim, cfg.dim, cfg.kind, rng);
            (blocks, out)
        };
        let (image_blocks, image_out) = build_tower(cfg.patches, &mut rng);
        let (text_blocks, text_out) = build_tower(cfg.text_seq, &mut rng);
        Self {
            cfg,
            patch_embed,
            tok_embed,
            image_blocks,
            image_out,
            text_blocks,
            text_out,
            log_scale: super::loss::init_log_scale(),
        }
    }

    // ----- forward ----------------------------------------------------

    /// Tower forward with caches: blocks → mean-pool → out-proj → L2
    /// normalize.  Pooling and normalization use the shared `nn` helpers
    /// that `serve::encoder::Tower::encode` also calls (bit-equality at
    /// init is structural, not mirrored by hand).
    fn tower_forward(
        blocks: &[TransformerBlock],
        out_proj: &Linear,
        seq: usize,
        dim: usize,
        mut x: Matrix,
    ) -> TowerCache {
        let mut caches = Vec::with_capacity(blocks.len());
        for blk in blocks {
            let (y, c) = blk.forward(&x);
            caches.push(c);
            x = y;
        }
        let pooled = mean_pool_rows(&x, seq, dim);
        let (emb, out_cache) = out_proj.forward(&pooled);
        let mut z = emb;
        let norms = l2_normalize_rows(&mut z);
        TowerCache { blocks: caches, out: out_cache, norms, z }
    }

    /// Full forward over a sub-batch: `images` is `[n·patches, patch_dim]`
    /// (see `data::Batch::images_matrix`), `tokens` is `n·text_seq` ids.
    pub fn forward(&self, images: &Matrix, tokens: &[i32]) -> FwdCache {
        let c = &self.cfg;
        assert_eq!(images.cols, c.patch_dim, "image patch width");
        assert_eq!(images.rows % c.patches, 0, "image row count");
        assert_eq!(tokens.len() % c.text_seq, 0, "token count");
        assert_eq!(
            images.rows / c.patches,
            tokens.len() / c.text_seq,
            "towers disagree on batch size"
        );
        let (h, img_pe) = self.patch_embed.forward(images);
        let img_tower =
            Self::tower_forward(&self.image_blocks, &self.image_out, c.patches, c.dim, h);
        let mut x = Matrix::zeros(tokens.len(), c.dim);
        let mut txt_tokens = Vec::with_capacity(tokens.len());
        for (j, &tok) in tokens.iter().enumerate() {
            let tok = tok.rem_euclid(c.vocab as i32) as usize;
            txt_tokens.push(tok);
            x.row_mut(j).copy_from_slice(self.tok_embed.row(tok));
        }
        let txt_tower =
            Self::tower_forward(&self.text_blocks, &self.text_out, c.text_seq, c.dim, x);
        FwdCache { img_pe, img_tower, txt_tokens, txt_tower }
    }

    // ----- backward ---------------------------------------------------

    /// Backward through L2-normalize: `z = e/‖e‖` ⇒
    /// `de = (dz − z ⟨z, dz⟩) / ‖e‖` per row.
    fn norm_backward(cache: &TowerCache, dz: &Matrix) -> Matrix {
        let mut de = dz.clone();
        for r in 0..dz.rows {
            let n = cache.norms[r];
            if n <= 0.0 {
                continue; // forward left the row untouched
            }
            let zrow = cache.z.row(r);
            let drow = de.row_mut(r);
            let dot: f32 = zrow.iter().zip(drow.iter()).map(|(a, b)| a * b).sum();
            for (d, &zv) in drow.iter_mut().zip(zrow) {
                *d = (*d - zv * dot) / n;
            }
        }
        de
    }

    /// Backward through one tower; returns `(d_input, per-block grads in
    /// forward order, out-proj grad)`.
    fn tower_backward(
        blocks: &[TransformerBlock],
        out_proj: &Linear,
        cache: &TowerCache,
        seq: usize,
        dim: usize,
        dz: &Matrix,
    ) -> (Matrix, Vec<[Matrix; 6]>, Matrix) {
        let de = Self::norm_backward(cache, dz);
        let (dpooled, dw_out) = out_proj.backward(&cache.out, &de);
        // un-pool: each of an item's seq rows receives dpooled/seq
        let b = dpooled.rows;
        let mut dx = Matrix::zeros(b * seq, dim);
        let inv = 1.0 / seq as f32;
        for i in 0..b {
            let prow = dpooled.row(i);
            for t in 0..seq {
                let xrow = dx.row_mut(i * seq + t);
                for (x, &p) in xrow.iter_mut().zip(prow) {
                    *x = p * inv;
                }
            }
        }
        let mut block_grads: Vec<[Matrix; 6]> = Vec::with_capacity(blocks.len());
        for (blk, bc) in blocks.iter().zip(&cache.blocks).rev() {
            let (dxi, grads) = blk.backward(bc, &dx);
            dx = dxi;
            block_grads.push(grads.into_array());
        }
        block_grads.reverse(); // forward order, matching the param layout
        (dx, block_grads, dw_out)
    }

    /// Full backward: upstream gradients on the *normalized* embeddings →
    /// flat per-tensor gradients aligned with [`Self::param_metas`].  The
    /// logit-scale slot (last) is left at zero — the loss's `d_log_scale`
    /// is global, so the trainer adds it once after summing shard grads.
    pub fn backward(&self, cache: &FwdCache, d_img: &Matrix, d_txt: &Matrix) -> Vec<Vec<f32>> {
        let c = &self.cfg;
        let (dh, img_blocks, dw_img_out) = Self::tower_backward(
            &self.image_blocks,
            &self.image_out,
            &cache.img_tower,
            c.patches,
            c.dim,
            d_img,
        );
        let (_, dw_pe) = self.patch_embed.backward(&cache.img_pe, &dh);
        let (dx_txt, txt_blocks, dw_txt_out) = Self::tower_backward(
            &self.text_blocks,
            &self.text_out,
            &cache.txt_tower,
            c.text_seq,
            c.dim,
            d_txt,
        );
        let mut dtok = Matrix::zeros(c.vocab, c.dim);
        for (r, &tok) in cache.txt_tokens.iter().enumerate() {
            let src = dx_txt.row(r);
            let dst = dtok.row_mut(tok);
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }

        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(self.n_params());
        grads.push(dw_pe.data);
        grads.push(dtok.data);
        for blk in img_blocks {
            for g in blk {
                grads.push(g.data);
            }
        }
        grads.push(dw_img_out.data);
        for blk in txt_blocks {
            for g in blk {
                grads.push(g.data);
            }
        }
        grads.push(dw_txt_out.data);
        grads.push(vec![0.0]); // logit scale: filled in by the trainer
        grads
    }

    // ----- inference (eval path) --------------------------------------

    // `forward_infer` quantizes weights per call but shares the serve
    // path's blocked int8 kernels and fused-quantize block wiring (one
    // activation quantize for Q/K/V, GELU fused into the up-proj
    // epilogue) via the same `MatmulPlan` dispatch — which is what keeps
    // eval encodings bit-identical to a prepared serving encoder's.

    fn tower_infer(
        blocks: &[TransformerBlock],
        out_proj: &Linear,
        seq: usize,
        dim: usize,
        mut x: Matrix,
    ) -> Matrix {
        for blk in blocks {
            x = blk.forward_infer(&x);
        }
        let pooled = mean_pool_rows(&x, seq, dim);
        let mut z = out_proj.forward_infer(&pooled);
        l2_normalize_rows(&mut z);
        z
    }

    /// Cache-free image encode (eval path): `[n·patches, patch_dim]` →
    /// L2-normalized `[n, embed_dim]`.
    pub fn encode_images_infer(&self, images: &Matrix) -> Matrix {
        let c = &self.cfg;
        let h = self.patch_embed.forward_infer(images);
        Self::tower_infer(&self.image_blocks, &self.image_out, c.patches, c.dim, h)
    }

    /// Cache-free text encode (eval path): `n·text_seq` token ids →
    /// L2-normalized `[n, embed_dim]`.
    pub fn encode_texts_infer(&self, tokens: &[i32]) -> Matrix {
        let c = &self.cfg;
        let mut x = Matrix::zeros(tokens.len(), c.dim);
        for (j, &tok) in tokens.iter().enumerate() {
            let tok = tok.rem_euclid(c.vocab as i32) as usize;
            x.row_mut(j).copy_from_slice(self.tok_embed.row(tok));
        }
        Self::tower_infer(&self.text_blocks, &self.text_out, c.text_seq, c.dim, x)
    }

    // ----- parameter registry -----------------------------------------

    /// Optimizer metadata, index-aligned with [`Self::collect_params`] and
    /// [`Self::backward`]'s gradient layout.
    pub fn param_metas(&self) -> Vec<ParamMeta> {
        let mut metas = vec![
            ParamMeta {
                name: "patch_embed".into(),
                decay: true,
                kind: "patch_embed".into(),
            },
            ParamMeta::no_decay("tok_embed", "embedding"),
        ];
        for (tower, n_blocks) in
            [("img", self.image_blocks.len()), ("txt", self.text_blocks.len())]
        {
            for b in 0..n_blocks {
                for p in PROJ_NAMES {
                    metas.push(ParamMeta::weight(&format!("{tower}.block{b}.{p}")));
                }
            }
            metas.push(ParamMeta::weight(&format!("{tower}.out_proj")));
        }
        metas.push(ParamMeta::no_decay("logit_scale", "temperature"));
        metas
    }

    pub fn n_params(&self) -> usize {
        2 + 6 * (self.image_blocks.len() + self.text_blocks.len()) + 2 + 1
    }

    /// Copy all trainable tensors into flat per-tensor buffers (the
    /// optimizer's working set).
    pub fn collect_params(&self) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(self.n_params());
        out.push(self.patch_embed.w.data.clone());
        out.push(self.tok_embed.data.clone());
        for (blocks, out_proj) in [
            (&self.image_blocks, &self.image_out),
            (&self.text_blocks, &self.text_out),
        ] {
            for blk in blocks.iter() {
                for lin in blk.projections() {
                    out.push(lin.w.data.clone());
                }
            }
            out.push(out_proj.w.data.clone());
        }
        out.push(vec![self.log_scale]);
        out
    }

    /// Write updated flat buffers back into the model tensors.
    pub fn load_params(&mut self, params: &[Vec<f32>]) {
        assert_eq!(params.len(), self.n_params(), "param layout mismatch");
        let mut it = params.iter();
        let mut next = |dst: &mut [f32]| {
            let src = it.next().expect("param layout");
            dst.copy_from_slice(src);
        };
        next(&mut self.patch_embed.w.data);
        next(&mut self.tok_embed.data);
        for blocks_out in [
            (&mut self.image_blocks, &mut self.image_out),
            (&mut self.text_blocks, &mut self.text_out),
        ] {
            let (blocks, out_proj) = blocks_out;
            for blk in blocks.iter_mut() {
                for lin in blk.projections_mut() {
                    next(&mut lin.w.data);
                }
            }
            next(&mut out_proj.w.data);
        }
        let last = it.next().expect("param layout");
        self.log_scale = last[0];
        assert!(it.next().is_none(), "param layout mismatch");
    }

    /// `(patch_embed, mid-transformer control)` probe indices into the
    /// param layout — the same pair the PJRT trainer probes (Fig 9 vs the
    /// Fig 21 control).  With no blocks (degenerate configs) the image
    /// out-projection stands in as the control tensor.
    pub fn probe_indices(&self) -> (usize, usize) {
        if self.image_blocks.is_empty() {
            return (0, 2); // img.out_proj
        }
        let mid_block = self.image_blocks.len() / 2;
        (0, 2 + mid_block * 6 + 4) // w1 (mlp up) of the middle image block
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LinearKind;
    use crate::serve::ClipEncoder;

    fn tiny(kind: LinearKind) -> EncoderConfig {
        EncoderConfig {
            kind,
            dim: 16,
            heads: 2,
            blocks: 2,
            embed_dim: 8,
            patches: 4,
            patch_dim: 12,
            text_seq: 5,
            vocab: 64,
            seed: 7,
        }
    }

    /// The shared-seeding contract: a fresh train model and the serving
    /// encoder built from the same config encode bit-identically.
    #[test]
    fn init_matches_serving_encoder_bit_for_bit() {
        for kind in [LinearKind::Standard, LinearKind::SwitchBack] {
            let cfg = tiny(kind);
            let model = ClipTrainModel::new(cfg.clone());
            let enc = ClipEncoder::new(cfg.clone());
            let mut rng = Rng::seed(5);
            let img: Vec<f32> = (0..cfg.image_len()).map(|_| rng.normal()).collect();
            let toks: Vec<i32> =
                (0..cfg.text_seq).map(|_| rng.below(cfg.vocab) as i32).collect();
            let m_img = model.encode_images_infer(&Matrix::from_vec(
                cfg.patches,
                cfg.patch_dim,
                img.clone(),
            ));
            let e_img = &enc.encode_images(&[&img])[0];
            assert_eq!(m_img.row(0), &e_img[..], "{kind:?} image tower drifted");
            let m_txt = model.encode_texts_infer(&toks);
            let e_txt = &enc.encode_texts(&[&toks])[0];
            assert_eq!(m_txt.row(0), &e_txt[..], "{kind:?} text tower drifted");
        }
    }

    #[test]
    fn param_roundtrip_and_layout() {
        let mut model = ClipTrainModel::new(tiny(LinearKind::Standard));
        let metas = model.param_metas();
        let mut params = model.collect_params();
        assert_eq!(metas.len(), params.len());
        assert_eq!(metas.len(), model.n_params());
        assert_eq!(metas[0].name, "patch_embed");
        assert_eq!(metas.last().unwrap().name, "logit_scale");
        assert_eq!(params.last().unwrap().len(), 1);
        // perturb, load, re-collect: identical
        for p in params.iter_mut() {
            for v in p.iter_mut() {
                *v += 0.125;
            }
        }
        model.load_params(&params);
        assert_eq!(model.collect_params(), params);
        let (pe, mid) = model.probe_indices();
        assert_eq!(pe, 0);
        assert!(metas[mid].name.contains("block"), "{}", metas[mid].name);
    }

    /// Gradient shapes line up with parameter shapes.
    #[test]
    fn backward_layout_matches_params() {
        let model = ClipTrainModel::new(tiny(LinearKind::Standard));
        let cfg = &model.cfg;
        let mut rng = Rng::seed(9);
        let n = 3;
        let images = Matrix::randn(n * cfg.patches, cfg.patch_dim, 0.5, &mut rng);
        let tokens: Vec<i32> =
            (0..n * cfg.text_seq).map(|_| rng.below(cfg.vocab) as i32).collect();
        let cache = model.forward(&images, &tokens);
        assert_eq!(cache.img_z().rows, n);
        assert_eq!(cache.txt_z().cols, cfg.embed_dim);
        let dz_i = Matrix::randn(n, cfg.embed_dim, 1.0, &mut rng);
        let dz_t = Matrix::randn(n, cfg.embed_dim, 1.0, &mut rng);
        let grads = model.backward(&cache, &dz_i, &dz_t);
        let params = model.collect_params();
        assert_eq!(grads.len(), params.len());
        for (g, p) in grads.iter().zip(&params) {
            assert_eq!(g.len(), p.len());
        }
        // token rows that never appeared get zero embedding grads
        let used: std::collections::HashSet<usize> =
            tokens.iter().map(|&t| t as usize).collect();
        for tok in 0..cfg.vocab {
            let row = &grads[1][tok * cfg.dim..(tok + 1) * cfg.dim];
            let zero = row.iter().all(|&v| v == 0.0);
            if !used.contains(&tok) {
                assert!(zero, "unused token {tok} has gradient");
            }
        }
    }

    /// End-to-end finite-difference spot-check through the whole chain:
    /// contrastive loss → normalize → out-proj → pool → blocks → embeds.
    #[test]
    fn end_to_end_gradients_match_finite_difference() {
        use crate::train::loss::clip_contrastive;
        let cfg = EncoderConfig {
            kind: LinearKind::Standard,
            dim: 8,
            heads: 2,
            blocks: 1,
            embed_dim: 4,
            patches: 3,
            patch_dim: 5,
            text_seq: 3,
            vocab: 16,
            seed: 11,
        };
        let mut model = ClipTrainModel::new(cfg.clone());
        let mut rng = Rng::seed(12);
        let n = 3;
        let images = Matrix::randn(n * cfg.patches, cfg.patch_dim, 0.7, &mut rng);
        let tokens: Vec<i32> =
            (0..n * cfg.text_seq).map(|_| rng.below(cfg.vocab) as i32).collect();

        let loss_of = |model: &ClipTrainModel| -> f32 {
            let cache = model.forward(&images, &tokens);
            clip_contrastive(cache.img_z(), cache.txt_z(), model.log_scale).loss
        };
        let cache = model.forward(&images, &tokens);
        let out = clip_contrastive(cache.img_z(), cache.txt_z(), model.log_scale);
        let mut grads = model.backward(&cache, &out.d_img, &out.d_txt);
        let last = grads.len() - 1;
        grads[last][0] = out.d_log_scale;

        let h = 1e-3;
        let check = |idx: usize, elems: &[usize], model: &mut ClipTrainModel| {
            let mut params = model.collect_params();
            for &e in elems {
                let orig = params[idx][e];
                params[idx][e] = orig + h;
                model.load_params(&params);
                let lp = loss_of(model);
                params[idx][e] = orig - h;
                model.load_params(&params);
                let lm = loss_of(model);
                params[idx][e] = orig;
                model.load_params(&params);
                let fd = (lp - lm) / (2.0 * h);
                let got = grads[idx][e];
                assert!(
                    (got - fd).abs() < 2e-2,
                    "param {idx} elem {e}: {got} vs fd {fd}"
                );
            }
        };
        // patch embed, token embed, a q-projection, out-projs, logit scale
        check(0, &[0, 7, 19], &mut model);
        let used_tok = tokens[0] as usize * cfg.dim;
        check(1, &[used_tok, used_tok + 3], &mut model);
        check(2, &[1, 30], &mut model); // img.block0.wq
        let metas = model.param_metas();
        let img_out = metas.iter().position(|m| m.name == "img.out_proj").unwrap();
        let txt_out = metas.iter().position(|m| m.name == "txt.out_proj").unwrap();
        check(img_out, &[0, 5], &mut model);
        check(txt_out, &[0, 5], &mut model);
        check(last, &[0], &mut model);
    }
}
