//! Serving throughput: Standard (f32) vs SwitchBack vs LLM.int8() on the
//! same weights, same batch policy, same closed-loop offered load.
//!
//! This is the serving analogue of Fig 13's end-to-end training speedup:
//! forward-only, so SwitchBack's advantage is pure int8-GEMM time (no
//! wgrad in sight) minus the activation-quantize overhead.  A second
//! cache-focused pass measures the hit path, which must be orders of
//! magnitude cheaper than any encode.
//!
//! Writes `results/serve_throughput.json` (same entry schema as
//! BENCH_serve.json) so CI can track the trajectory.
//!
//! Usage: `cargo bench --bench serve_throughput [-- --quick]`

use std::time::Duration;
use switchback::nn::LinearKind;
use switchback::serve::{
    run_loadgen, write_bench_json, BatchPolicy, EncoderConfig, Engine,
    LoadgenConfig, ServeConfig,
};

fn engine(kind: LinearKind, cache_capacity: usize, quick: bool) -> Engine {
    let mut enc = EncoderConfig::demo(kind);
    if quick {
        enc.blocks = 1;
        enc.dim = 64;
    }
    Engine::start(ServeConfig {
        encoder: enc,
        policy: BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_micros(2000),
        },
        workers: 0,
        cache_capacity,
        cache_shards: 0,
    })
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let requests = if quick { 300 } else { 3000 };
    let population = requests / 2;
    println!("== serve throughput: precision kinds at equal batch policy ==");
    println!("   {requests} requests, population {population}, concurrency 32\n");

    let kinds = [
        LinearKind::Standard,
        LinearKind::SwitchBack,
        LinearKind::LlmInt8,
    ];
    let mut reports = vec![];
    for kind in kinds {
        // 2× the population: per-shard caps + hash imbalance would evict
        // live members at exactly-sized capacity
        let eng = engine(kind, (population * 2).max(2), quick);
        let report = run_loadgen(
            &eng,
            &LoadgenConfig {
                requests,
                concurrency: 32,
                population,
                image_fraction: 0.7,
                seed: 77,
                swap_every: 0,
            },
        );
        report.print();
        reports.push(report);
        eng.shutdown();
    }

    if let (Some(std_r), Some(sb_r)) = (
        reports.iter().find(|r| r.kind == "standard"),
        reports.iter().find(|r| r.kind == "switchback"),
    ) {
        println!(
            "\nswitchback vs standard serving throughput: {:.2}×",
            sb_r.requests_per_sec / std_r.requests_per_sec
        );
    }

    // hit-path microcheck: repeats must be far cheaper than encodes
    let eng = engine(LinearKind::SwitchBack, 64, true);
    let report = run_loadgen(
        &eng,
        &LoadgenConfig {
            requests: 2000,
            concurrency: 8,
            population: 8,
            image_fraction: 1.0,
            seed: 3,
            swap_every: 0,
        },
    );
    println!(
        "\nhit path: hit-rate {:.1}%  hit p50 {:.4} ms  vs encode p50 {:.3} ms",
        100.0 * report.snapshot.hit_rate,
        report.snapshot.hit_p50_ms,
        report.snapshot.request_p50_ms,
    );
    reports.push(report);
    eng.shutdown();

    std::fs::create_dir_all("results").ok();
    let out = "results/serve_throughput.json";
    match write_bench_json(out, 32, 2000, &reports) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
