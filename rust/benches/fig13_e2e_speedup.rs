//! Fig 4 (right) + Fig 13: end-to-end transformer-block training-step
//! speedups across model sizes, for SwitchBack vs the standard layer
//! (Fig 4 right) and vs LLM.int8() (Fig 13).
//!
//! Paper setup: CLIP ViT-{M,B,L,H} on 4×A100; every linear in the block is
//! replaced per variant, everything else (layernorm/softmax/residuals)
//! stays float.  Here: full fwd+bwd of a transformer block on the native
//! substrate at the matching widths.  SwitchBackM is included to show the
//! Algorithm 3 memory/runtime trade.

use switchback::nn::{LinearKind, TransformerBlock};
use switchback::tensor::{Matrix, Rng};
use switchback::util::bench::bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // (name, dim) ~ CLIP ViT-M/B/L/H widths
    let sizes: &[(&str, usize)] = if quick {
        &[("vit-m", 512), ("vit-b", 768)]
    } else {
        &[("vit-m", 512), ("vit-b", 768), ("vit-l", 1024)]
    };
    let samples = 3;
    let seq = 32;
    let batch = 2;
    println!("== Fig 4 (right) + Fig 13: end-to-end block train-step times ==\n");
    println!("  size    dim    standard    switchback  switchbackM  llmint8     | sb vs std   llm vs std");
    let mut table = vec![];
    for &(name, dim) in sizes {
        let heads = dim / 64;
        let mut rng = Rng::seed(3);
        let x = Matrix::randn(batch * seq, dim, 0.5, &mut rng);
        let mut times = vec![];
        for kind in [
            LinearKind::Standard,
            LinearKind::SwitchBack,
            LinearKind::SwitchBackM,
            LinearKind::LlmInt8,
        ] {
            let blk = TransformerBlock::new(dim, heads, seq, kind, &mut Rng::seed(5));
            let r = bench(kind.label(), samples, || {
                let _ = blk.train_step_compute(&x);
            });
            times.push(r.median_ns / 1e6);
        }
        let sb = 100.0 * (times[0] - times[1]) / times[0];
        let llm = 100.0 * (times[0] - times[3]) / times[0];
        println!(
            "  {name:<6} {dim:<6} {:>9.2}   {:>9.2}   {:>9.2}   {:>9.2}   | {sb:+8.1}%   {llm:+8.1}%",
            times[0], times[1], times[2], times[3]
        );
        table.push((name, sb, llm));
    }
    println!("\n== summary: % end-to-end speedup over the standard layer ==");
    for (name, sb, llm) in &table {
        println!("  {name:<6} switchback {sb:+6.1}%   llmint8 {llm:+6.1}%");
    }
    println!("\n  (paper Fig 4-right: SwitchBack speedup grows ViT-B→ViT-H, 13–25%;");
    println!("   paper Fig 13: LLM.int8() provides NO speedup at these scales)");
}
