//! Fig 3: per-operation profile of a linear layer, SwitchBack vs standard.
//!
//! Paper setup: dims 512–4096, time each op in the fwd+bwd of dim→4·dim and
//! 4·dim→dim layers (a transformer MLP) with b = 16·dim rows; then report
//! the % speedup of SwitchBack's summed ops over the standard layer's.
//! Substrate substitution: the packed blocked int8 GEMM vs f32 GEMM instead
//! of Triton int8 vs fp16 cuBLAS — the shape (int8 matmuls faster than the
//! float ones, quantize ops an order of magnitude cheaper, advantage grows
//! with dim) carries.  The int8 bars time the packed kernel with the
//! quantize+pack cost measured as its own bar, mirroring how
//! [`switchback::gemm::MatmulPlan::forward`] pays it per training call.

use switchback::gemm::{gemm_i8_packed, MatmulPlan, PackedInt8};
use switchback::quant::{rowwise_quant, QuantScheme};
use switchback::tensor::{Matrix, Rng};
use switchback::util::bench::{bench, BenchResult};

fn ms(r: &BenchResult) -> f64 {
    r.median_ns / 1e6
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dims: &[usize] = if quick { &[128, 256] } else { &[128, 256, 512] };
    let samples = 3;
    let standard = MatmulPlan::standard();
    let switchback = MatmulPlan::switchback(false);
    println!("== Fig 3 (left): per-op times, averaged over dim→4dim and 4dim→dim ==");
    println!("   b = 16·dim rows (batch×seq)\n");
    let mut rows = vec![];
    for &dim in dims {
        let b = 2 * dim; // paper uses 16·dim; 4·dim keeps CPU wall-time sane, ratios unchanged
        let mut rng = Rng::seed(42);
        // the two MLP layers: [4d, d] and [d, 4d]
        let shapes = [(4 * dim, dim), (dim, 4 * dim)];
        let mut t_std = 0.0;
        let mut t_sb = 0.0;
        let mut parts: Vec<(String, f64)> = vec![];
        for (m, n) in shapes {
            let x = Matrix::randn(b, n, 1.0, &mut rng);
            let w = Matrix::randn(m, n, 0.05, &mut rng);
            let g = Matrix::randn(b, m, 1.0, &mut rng);

            // --- standard (Algorithm 5): three float matmuls
            let r_fwd = bench("std fwd", samples, || {
                let _ = standard.forward(&x, &w);
            });
            let r_dg = bench("std dgrad", samples, || {
                let _ = standard.dgrad(&g, &w);
            });
            let r_wg = bench("std wgrad", samples, || {
                let _ = standard.wgrad(&g, &x);
            });
            t_std += ms(&r_fwd) + ms(&r_dg) + ms(&r_wg);

            // --- SwitchBack ops, individually (the Fig 3-left bars)
            let xq = rowwise_quant(&x);
            let gq = rowwise_quant(&g);
            let wp = PackedInt8::quantize(QuantScheme::TensorWise, &w);
            let wtp = PackedInt8::quantize(QuantScheme::TensorWiseTranspose, &w);
            let r_qx = bench("quantize x (rowwise)", samples, || {
                let _ = rowwise_quant(&x);
            });
            let r_qw = bench("quantize+pack w (tensorwise)", samples, || {
                let _ = PackedInt8::quantize(QuantScheme::TensorWise, &w);
            });
            let r_qwt = bench("quantize+transpose+pack w (fused)", samples, || {
                let _ = PackedInt8::quantize(QuantScheme::TensorWiseTranspose, &w);
            });
            let r_i8f = bench("int8 blocked matmul+dequant (fwd)", samples, || {
                let _ = gemm_i8_packed(&xq, &wp);
            });
            let r_i8d = bench("int8 blocked matmul+dequant (dgrad)", samples, || {
                let _ = gemm_i8_packed(&gq, &wtp);
            });
            let r_wg16 = bench("f32 wgrad (kept high precision)", samples, || {
                let _ = switchback.wgrad(&g, &x);
            });
            t_sb += ms(&r_qx) + ms(&r_qw) + ms(&r_qwt) + ms(&r_i8f) + ms(&r_i8d)
                + ms(&r_wg16);
            for r in [&r_qx, &r_qw, &r_qwt, &r_i8f, &r_i8d, &r_wg16, &r_fwd, &r_dg, &r_wg]
            {
                parts.push((r.name.clone(), ms(r)));
            }
        }
        println!("dim = {dim} (b = {b}):");
        // aggregate the two shapes per op name
        let mut agg: std::collections::BTreeMap<String, f64> = Default::default();
        for (name, t) in parts {
            *agg.entry(name).or_default() += t;
        }
        for (name, t) in &agg {
            println!("    {name:<34} {t:9.3} ms");
        }
        let speedup = 100.0 * (t_std - t_sb) / t_std;
        println!(
            "    TOTAL  standard {t_std:9.3} ms | switchback {t_sb:9.3} ms  →  \
             speedup {speedup:+.1}%\n"
        );
        rows.push((dim, speedup));
    }
    println!("== Fig 3 (right): % speedup of SwitchBack vs dim ==");
    for (dim, s) in &rows {
        println!("  dim {dim:<6} {s:+6.1}%");
    }
    println!("  (paper: 5%–35%, increasing with dim — the quantize overhead is O(n²) vs O(n³))");
}
