//! Fig 4 (left): % of a SwitchBack linear layer's time spent in quantize
//! ops, as a function of dim.  Paper: ≤25%, falling to ~10% at large dim
//! (quantize is O(n²) against the matmul's O(n³)).
//!
//! Matmuls run on the packed blocked kernel (weights packed outside the
//! timer, as the prepare path does); quantize ops cover both activation
//! row-quantize and the weight quantize+pack the training forward pays
//! per call.  `--out <path>` writes a `gemm_quant_fraction` artifact the
//! `gemm_roofline` bench embeds into BENCH_gemm.json for the CI gate.

use switchback::gemm::{gemm_i8_packed, MatmulPlan, PackedInt8};
use switchback::quant::{rowwise_quant, QuantScheme};
use switchback::tensor::{Matrix, Rng};
use switchback::util::bench::bench;
use switchback::util::json::ObjWriter;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned());
    let dims: &[usize] = if quick { &[128, 256] } else { &[128, 256, 512] };
    let samples = 3;
    let plan = MatmulPlan::switchback(false);
    println!("== Fig 4 (left): fraction of SwitchBack layer time in quantize ops ==\n");
    println!("  dim     quantize-ms   matmul-ms   quant %");
    let mut rows = Vec::new();
    for &dim in dims {
        let b = 2 * dim; // see fig3 note
        let (m, n) = (4 * dim, dim);
        let mut rng = Rng::seed(7);
        let x = Matrix::randn(b, n, 1.0, &mut rng);
        let w = Matrix::randn(m, n, 0.05, &mut rng);
        let g = Matrix::randn(b, m, 1.0, &mut rng);
        let xq = rowwise_quant(&x);
        let gq = rowwise_quant(&g);
        let wp = PackedInt8::quantize(QuantScheme::TensorWise, &w);
        let wtp = PackedInt8::quantize(QuantScheme::TensorWiseTranspose, &w);

        let q = bench("quant", samples, || {
            let _ = rowwise_quant(&x);
            let _ = rowwise_quant(&g);
            let _ = PackedInt8::quantize(QuantScheme::TensorWise, &w);
            let _ = PackedInt8::quantize(QuantScheme::TensorWiseTranspose, &w);
        })
        .median_ns;
        let mm = bench("matmuls", samples, || {
            let _ = gemm_i8_packed(&xq, &wp); // fwd
            let _ = gemm_i8_packed(&gq, &wtp); // dgrad
            let _ = plan.wgrad(&g, &x); // f32 wgrad (kept high precision)
        })
        .median_ns;
        let frac = 100.0 * q / (q + mm);
        println!(
            "  {dim:<6} {:>10.3}   {:>10.3}   {frac:5.1}%",
            q / 1e6,
            mm / 1e6
        );
        rows.push((dim, q / 1e6, mm / 1e6, frac));
    }
    println!("\n  (paper: ≤25%, decreasing with dim)");

    if let Some(path) = out_path {
        let entries: Vec<String> = rows
            .iter()
            .map(|&(dim, quant_ms, matmul_ms, pct)| {
                let mut o = ObjWriter::new();
                o.field_u64("dim", dim as u64)
                    .field_f32("quant_ms", quant_ms as f32)
                    .field_f32("matmul_ms", matmul_ms as f32)
                    .field_f32("quant_pct", pct as f32);
                o.finish()
            })
            .collect();
        let mut top = ObjWriter::new();
        top.field_str("bench", "gemm_quant_fraction")
            .field_raw("results", &format!("[{}]", entries.join(",")));
        std::fs::write(&path, top.finish() + "\n").expect("write --out");
        println!("wrote {path}");
    }
}
