//! Fig 4 (left): % of a SwitchBack linear layer's time spent in quantize
//! ops, as a function of dim.  Paper: ≤25%, falling to ~10% at large dim
//! (quantize is O(n²) against the matmul's O(n³)).

use switchback::gemm::{gemm_i8_nt_rowtensor, SwitchBackOps};
use switchback::quant::{rowwise_quant, tensorwise_quant, tensorwise_quant_transpose};
use switchback::tensor::{Matrix, Rng};
use switchback::util::bench::bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dims: &[usize] = if quick { &[128, 256] } else { &[128, 256, 512] };
    let samples = 3;
    println!("== Fig 4 (left): fraction of SwitchBack layer time in quantize ops ==\n");
    println!("  dim     quantize-ms   matmul-ms   quant %");
    for &dim in dims {
        let b = 2 * dim; // see fig3 note
        let (m, n) = (4 * dim, dim);
        let mut rng = Rng::seed(7);
        let x = Matrix::randn(b, n, 1.0, &mut rng);
        let w = Matrix::randn(m, n, 0.05, &mut rng);
        let g = Matrix::randn(b, m, 1.0, &mut rng);
        let xq = rowwise_quant(&x);
        let wq = tensorwise_quant(&w);
        let gq = rowwise_quant(&g);
        let wtq = tensorwise_quant_transpose(&w);

        let q = bench("quant", samples, || {
            let _ = rowwise_quant(&x);
            let _ = tensorwise_quant(&w);
            let _ = rowwise_quant(&g);
            let _ = tensorwise_quant_transpose(&w);
        })
        .median_ns;
        let mm = bench("matmuls", samples, || {
            let _ = gemm_i8_nt_rowtensor(&xq, &wq);
            let _ = gemm_i8_nt_rowtensor(&gq, &wtq);
            let _ = SwitchBackOps::wgrad(&g, &x);
        })
        .median_ns;
        let frac = 100.0 * q / (q + mm);
        println!(
            "  {dim:<6} {:>10.3}   {:>10.3}   {frac:5.1}%",
            q / 1e6,
            mm / 1e6
        );
    }
    println!("\n  (paper: ≤25%, decreasing with dim)");
}
