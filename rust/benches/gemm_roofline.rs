//! GEMM kernel shootout + roofline: f32 reference vs the flat int8
//! reference kernel vs the packed cache-blocked kernel, at serve shapes.
//!
//! Emits `BENCH_gemm.json` (`--out`, kind `gemm_kernels`) — the artifact
//! `scripts/check_bench.sh` gates: the blocked kernel must stay at least
//! as fast as the flat reference at the two largest shapes (portable
//! invariant; absolute ratios under `--strict`).  With `--quant <path>`
//! the quant-fraction results emitted by the `fig4_quant_fraction` bench
//! are embedded, so the gate sees one file.
//!
//! Shapes are `(b, k, m)`: activations `[b, k]` × weight `[m, k]` — the
//! serve encoder's projection shapes (b = batch×seq rows).

use switchback::gemm::{
    gemm_f32_nt, gemm_i8_nt_rowtensor, gemm_i8_packed, kernel_isa, PackedInt8,
};
use switchback::quant::{rowwise_quant, tensorwise_quant};
use switchback::tensor::{Matrix, Rng};
use switchback::util::bench::bench;
use switchback::util::json::{self, ObjWriter};
use switchback::util::threads::num_threads;

struct ShapeResult {
    name: String,
    b: usize,
    k: usize,
    m: usize,
    f32_ms: f64,
    reference_ms: f64,
    blocked_ms: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let flag = |name: &str| -> Option<String> {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let out_path = flag("--out");
    let quant_path = flag("--quant");

    // (b, k, m); the --quick set is exactly the committed-baseline set
    // (benchmarks/BENCH_gemm.baseline.json) — benchdiff name-matches.
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(256, 256, 256), (512, 128, 512), (512, 512, 512)]
    } else {
        &[
            (256, 256, 256),
            (512, 128, 128),
            (512, 128, 512),
            (512, 512, 512),
            (1024, 512, 512),
        ]
    };
    let samples = 3;
    println!("threads: {}  kernel isa: {}\n", num_threads(), kernel_isa().label());
    println!("  shape                f32-ms   ref-i8-ms   blocked-ms   blocked-vs-ref   int8-vs-f32");
    let mut results = Vec::new();
    for &(b, k, m) in shapes {
        let mut rng = Rng::seed(1);
        let x = Matrix::randn(b, k, 1.0, &mut rng);
        let w = Matrix::randn(m, k, 0.1, &mut rng);
        let xq = rowwise_quant(&x);
        let wq = tensorwise_quant(&w);
        // pack once, outside the timer — serving packs at prepare/load time
        let wp = PackedInt8::pack_tensorwise(&wq);

        let r_f32 = bench("f32 NT", samples, || {
            let _ = gemm_f32_nt(&x, &w);
        });
        let r_ref = bench("reference i8", samples, || {
            let _ = gemm_i8_nt_rowtensor(&xq, &wq);
        });
        let r_blk = bench("blocked i8", samples, || {
            let _ = gemm_i8_packed(&xq, &wp);
        });
        let sr = ShapeResult {
            name: format!("b{b}_k{k}_m{m}"),
            b,
            k,
            m,
            f32_ms: r_f32.median_ns / 1e6,
            reference_ms: r_ref.median_ns / 1e6,
            blocked_ms: r_blk.median_ns / 1e6,
        };
        println!(
            "  {:<18} {:>9.3}   {:>9.3}   {:>10.3}   {:>13.2}x   {:>10.2}x",
            sr.name,
            sr.f32_ms,
            sr.reference_ms,
            sr.blocked_ms,
            sr.reference_ms / sr.blocked_ms,
            sr.f32_ms / sr.blocked_ms,
        );
        results.push(sr);
    }

    if let Some(path) = out_path {
        let entries: Vec<String> = results
            .iter()
            .map(|s| {
                let mut o = ObjWriter::new();
                o.field_str("name", &s.name)
                    .field_u64("b", s.b as u64)
                    .field_u64("k", s.k as u64)
                    .field_u64("m", s.m as u64)
                    .field_f32("f32_ms", s.f32_ms as f32)
                    .field_f32("reference_ms", s.reference_ms as f32)
                    .field_f32("blocked_ms", s.blocked_ms as f32)
                    .field_f32(
                        "blocked_speedup",
                        (s.reference_ms / s.blocked_ms) as f32,
                    )
                    .field_f32("int8_vs_f32", (s.f32_ms / s.blocked_ms) as f32);
                o.finish()
            })
            .collect();
        let quant_raw = quant_path.map(|qp| match embed_quant(&qp) {
            Ok(raw) => raw,
            Err(e) => {
                eprintln!("could not embed quant fraction from {qp}: {e}");
                std::process::exit(1);
            }
        });
        let mut top = ObjWriter::new();
        top.field_str("bench", "gemm_kernels")
            .field_str("isa", kernel_isa().label())
            .field_u64("threads", num_threads() as u64)
            .field_raw("results", &format!("[{}]", entries.join(",")));
        if let Some(raw) = quant_raw {
            top.field_raw("quant_fraction", &raw);
        }
        std::fs::write(&path, top.finish() + "\n").expect("write --out");
        println!("\nwrote {path}");
    }
}

/// Re-serialize the `fig4_quant_fraction --out` results array so the gate
/// reads one artifact.  Fails loudly on schema drift — a silently dropped
/// field would make the benchdiff gate vacuous.
fn embed_quant(path: &str) -> Result<String, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let doc = json::parse(&text)?;
    if doc.get("bench").and_then(|b| b.as_str()) != Some("gemm_quant_fraction") {
        return Err("not a gemm_quant_fraction artifact".into());
    }
    let arr = doc
        .get("results")
        .and_then(|r| r.as_arr())
        .ok_or("no results array")?;
    let mut entries = Vec::new();
    for e in arr {
        let mut o = ObjWriter::new();
        let f = |k: &str| -> Result<f64, String> {
            e.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("missing field {k}"))
        };
        o.field_u64("dim", f("dim")? as u64)
            .field_f32("quant_ms", f("quant_ms")? as f32)
            .field_f32("matmul_ms", f("matmul_ms")? as f32)
            .field_f32("quant_pct", f("quant_pct")? as f32);
        entries.push(o.finish());
    }
    Ok(format!("[{}]", entries.join(",")))
}
