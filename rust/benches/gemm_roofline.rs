//! GEMM roofline: absolute throughput of the native kernels (GFLOP/s and
//! effective GB/s), used by EXPERIMENTS.md §Perf to argue how far the
//! substrate is from this machine's practical roofline, and to track the
//! perf-pass iterations.

use switchback::gemm::{gemm_f32_nn, gemm_f32_nt, gemm_i8_nt_rowtensor};
use switchback::quant::{rowwise_quant, tensorwise_quant};
use switchback::tensor::{Matrix, Rng};
use switchback::util::bench::bench;
use switchback::util::threads::num_threads;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[usize] = if quick { &[256] } else { &[256, 512] };
    let samples = 3;
    println!("threads: {}\n", num_threads());
    println!("  n       kernel          median-ms   GFLOP/s (2n³/t)");
    for &n in sizes {
        let mut rng = Rng::seed(1);
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        let aq = rowwise_quant(&a);
        let bq = tensorwise_quant(&b);

        let r1 = bench("f32 NT", samples, || {
            let _ = gemm_f32_nt(&a, &b);
        });
        let r2 = bench("f32 NN", samples, || {
            let _ = gemm_f32_nn(&a, &b);
        });
        let r3 = bench("i8 NT (+dequant)", samples, || {
            let _ = gemm_i8_nt_rowtensor(&aq, &bq);
        });
        for r in [&r1, &r2, &r3] {
            println!(
                "  {n:<7} {:<15} {:>9.3}   {:>8.1}",
                r.name,
                r.median_ns / 1e6,
                flops / r.median_ns
            );
        }
        println!(
            "  {n:<7} int8/f32-NT ratio: {:.2}x",
            r1.median_ns / r3.median_ns
        );
        println!();
    }
}
