//! Compile-only stub of the `xla` crate's PJRT surface used by
//! [`switchback::runtime`].
//!
//! The offline build image does not ship the PJRT toolchain
//! (`xla_extension` + its C API shared objects), so this stub provides the
//! exact types and signatures the runtime layer compiles against, with
//! every entry point failing at *runtime* with a clear message.  On a
//! machine with the real toolchain, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual bindings (see `/opt/xla-example`) — no
//! source change needed, the API surface matches xla_extension 0.5.1.

use std::fmt;

/// Error for every stubbed entry point.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Self(format!(
            "{what}: PJRT toolchain not available in this build \
             (vendor/xla is a compile-only stub; see rust/Cargo.toml)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types accepted by [`Literal::vec1`] / [`Literal::to_vec`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_literal_sync"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Matches the real signature: returns per-device, per-output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<Literal>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loud_and_typed() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT toolchain not available"));
        // the literal builders are infallible (runtime constructs inputs
        // before executing), only execution paths error
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
