//! In-tree reimplementation of the `anyhow` API surface this project uses
//! (the build environment is offline — DESIGN.md §Substitutions lists the
//! vendored substrates).  Semantics match the real crate for everything we
//! call: `Result<T>`, `Error` with a blanket `From<E: std::error::Error>`,
//! the `anyhow!` / `bail!` macros, and `Context` on both `Result` and
//! `Option`.
//!
//! Like the real anyhow, [`Error`] deliberately does NOT implement
//! `std::error::Error` — that is what makes the blanket `From` impl legal.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: boxed cause + optional chain of context messages.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Build an error from a printable message (`anyhow!` expands to this).
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Self {
        Self { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Wrap an existing error with a context message, preserving the chain.
    fn context_of<C: fmt::Display>(self, context: C) -> Self {
        Self {
            inner: Box::new(ContextError {
                context: context.to_string(),
                source: self.inner,
            }),
        }
    }

    /// The root cause's message chain, outermost first.
    pub fn chain(&self) -> Vec<String> {
        let mut out = vec![self.inner.to_string()];
        let mut cur: Option<&(dyn StdError + 'static)> = self.inner.source();
        while let Some(e) = cur {
            out.push(e.to_string());
            cur = e.source();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)
    }
}

impl fmt::Debug for Error {
    /// Mirrors anyhow's report format: message, then the cause chain.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut cur: Option<&(dyn StdError + 'static)> = self.inner.source();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {e}")?;
            cur = e.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { inner: Box::new(e) }
    }
}

/// A plain-message error (no cause).
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// A context layer over an underlying error.
struct ContextError {
    context: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.context)
    }
}

impl fmt::Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {:?}", self.context, self.source)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(self.source.as_ref())
    }
}

/// Attach context to errors (`Result`) or turn `None` into an error.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error>;

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context_of(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context_of(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// `anyhow!("...{}...", args)` — construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// `bail!("...")` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains() {
        let e = io_fail().context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        let chain = e.chain();
        assert_eq!(chain, vec!["reading manifest".to_string(), "gone".to_string()]);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");

        fn bails(trigger: bool) -> Result<u32> {
            if trigger {
                bail!("bad value {:?}", 7);
            }
            Ok(1)
        }
        assert_eq!(bails(false).unwrap(), 1);
        assert_eq!(bails(true).unwrap_err().to_string(), "bad value 7");
        let e = anyhow!("plain {}", "msg");
        assert_eq!(e.to_string(), "plain msg");
    }
}
