#!/usr/bin/env bash
# Tier-1 verification + serve/train smokes + perf-trajectory artifacts.
#
# Usage: scripts/verify.sh [--full|--smoke]
#   default: tier-1 (build + tests) + serve smoke + a small loadgen run
#            + a 50-step native train smoke (loss must decrease)
#   --full : the 10k-request acceptance sweep + a 150-step train run
#   --smoke: skip `cargo test` (CI's bench-gate job runs after the
#            dedicated test job; the release build is incremental
#            against the restored cargo cache)
#
# Emits BENCH_serve.json, BENCH_train.json, BENCH_ckpt.json,
# BENCH_gemm.json and BENCH_lint.json at the repo root so the serving,
# training, checkpoint/hot-swap, GEMM-kernel and static-analysis
# trajectories are tracked across PRs (schemas: EXPERIMENTS.md §Serve /
# §Train / §Ckpt, gemm + lint: benchmarks/README.md).
# scripts/check_bench.sh gates all five against the committed baselines
# in benchmarks/.  Also emits
# BENCH_metrics.scrape.prom — one real /metrics scrape of the live
# telemetry plane (`--telemetry-addr`), uploaded by CI as the per-PR
# observability artifact.

set -euo pipefail
cd "$(dirname "$0")/.."
REPO_ROOT="$(pwd)"

MODE="${1:-}"
cd rust
if [[ "$MODE" == "--smoke" ]]; then
    echo "== build only (smoke mode): cargo build --release =="
    cargo build --release
else
    echo "== tier-1: cargo build --release && cargo test -q =="
    cargo build --release
    cargo test -q
fi

BIN=target/release/switchback

echo
echo "== lint: invariant linter + lock-order analysis (BENCH_lint.json) =="
# fail-closed: any unsuppressed finding (warn or error) fails verify; the
# ledger is gated by check_bench.sh so suppressions can only shrink
"$BIN" lint src --deny warn --out "$REPO_ROOT/BENCH_lint.json"
# the linter must still be able to fire: the committed should-fire
# fixture corpus has ≥1 violation per rule plus a two-lock cycle
if "$BIN" lint tests/fixtures/lint/fire --deny warn >/dev/null 2>&1; then
    echo "lint smoke FAILED: should-fire fixtures passed --deny warn" >&2
    exit 1
fi
"$BIN" lint tests/fixtures/lint/clean --deny warn >/dev/null \
    || { echo "lint smoke FAILED: should-not-fire fixtures fired" >&2; exit 1; }
echo "lint smoke OK — tree clean, fixtures fire/stay-quiet as committed"

echo
echo "== serve smoke =="
"$BIN" serve --kind switchback --requests 64

echo
echo "== telemetry smoke: serve --telemetry-addr → probe /healthz /readyz /metrics =="
# serve binds the plane on an ephemeral port, prints the address, and
# --hold-ms keeps it scrapeable after its own smoke probes; the probe
# subcommand polls until the plane answers.  The /metrics body is saved
# as the per-PR scrape artifact CI uploads.
TELEM_LOG="$REPO_ROOT/.verify_telemetry_serve.log"
SCRAPE_OUT="$REPO_ROOT/BENCH_metrics.scrape.prom"
rm -f "$TELEM_LOG" "$SCRAPE_OUT"
"$BIN" serve --kind switchback --requests 64 \
    --telemetry-addr 127.0.0.1:0 --hold-ms 6000 >"$TELEM_LOG" 2>&1 &
TELEM_PID=$!
TELEM_URL=""
for _ in $(seq 1 100); do
    TELEM_URL="$(sed -n 's/^telemetry: listening on //p' "$TELEM_LOG" | head -n 1)"
    [[ -n "$TELEM_URL" ]] && break
    sleep 0.1
done
[[ -n "$TELEM_URL" ]] || {
    echo "telemetry smoke FAILED: serve never printed the bound address" >&2
    cat "$TELEM_LOG" >&2
    kill "$TELEM_PID" 2>/dev/null || true
    exit 1
}
"$BIN" probe "$TELEM_URL/healthz" --expect '"ok":true' --follow 20 --every 100
"$BIN" probe "$TELEM_URL/readyz" --expect '"ready":true' --follow 20 --every 100
"$BIN" probe "$TELEM_URL/metrics" --follow 20 --every 100 \
    | tail -n +2 >"$SCRAPE_OUT"
grep -q '^serve_requests_total ' "$SCRAPE_OUT" \
    || { echo "telemetry smoke FAILED: no serve_requests_total in the /metrics scrape" >&2; exit 1; }
wait "$TELEM_PID" \
    || { echo "telemetry smoke FAILED: serve exited nonzero" >&2; cat "$TELEM_LOG" >&2; exit 1; }
grep -q "serve smoke OK" "$TELEM_LOG" \
    || { echo "telemetry smoke FAILED: held serve run did not finish its own smoke" >&2; exit 1; }
rm -f "$TELEM_LOG"
echo "telemetry smoke OK — /metrics scrape saved to BENCH_metrics.scrape.prom"

echo
echo "== loadgen (BENCH_serve.json) =="
if [[ "$MODE" == "--full" ]]; then
    REQUESTS=10000
    CONCURRENCY=32
    TRAIN_STEPS=150
    PIPE_STEPS=120
    PIPE_REQUESTS=2000
else
    REQUESTS=1000
    CONCURRENCY=16
    TRAIN_STEPS=50
    PIPE_STEPS=40
    PIPE_REQUESTS=256
fi
# --swap-every adds one swap-aware run: sustained throughput + tail
# latency across repeated generations, promoted through the standby path.
# --scrape-every adds one scraper-present run: a rider thread scrapes a
# live /metrics plane over the engine while the closed loop runs, so the
# benchdiff gate can hold "a concurrent scraper neither fails nor moves
# the serve tail" (benchmarks/README.md §Scrape metrics).
# --socket adds two real-TCP runs against the front door booted below
# (`serve --listen`, 2 engines behind a doc-hash router): a clean run at
# the base concurrency (zero errors, zero sheds — enforced by loadgen
# itself) and an overload run at 4× that concurrency (≥1 admission
# rejection required).  The server is held up with --hold-ms and killed
# once the artifact is written (benchmarks/README.md §Socket metrics)
FRONT_LOG="$REPO_ROOT/.verify_frontend_serve.log"
rm -f "$FRONT_LOG"
"$BIN" serve --kind switchback --requests 64 \
    --listen 127.0.0.1:0 --hold-ms 600000 >"$FRONT_LOG" 2>&1 &
FRONT_PID=$!
FRONT_ADDR=""
for _ in $(seq 1 100); do
    FRONT_ADDR="$(sed -n 's/^frontend: listening on \([^ ]*\).*/\1/p' "$FRONT_LOG" | head -n 1)"
    [[ -n "$FRONT_ADDR" ]] && break
    sleep 0.1
done
[[ -n "$FRONT_ADDR" ]] || {
    echo "socket smoke FAILED: serve --listen never printed the bound address" >&2
    cat "$FRONT_LOG" >&2
    kill "$FRONT_PID" 2>/dev/null || true
    exit 1
}
SWAP_EVERY=$((REQUESTS / 4))
"$BIN" loadgen \
    --requests "$REQUESTS" \
    --concurrency "$CONCURRENCY" \
    --kinds standard,switchback \
    --swap-every "$SWAP_EVERY" \
    --scrape-every 5 \
    --socket "$FRONT_ADDR" \
    --out "$REPO_ROOT/BENCH_serve.json"
grep -q '"standby_promotions":' "$REPO_ROOT/BENCH_serve.json" \
    || { echo "loadgen smoke FAILED: no standby promotions in BENCH_serve.json" >&2; exit 1; }
grep -q '"scrape_errors":0,' "$REPO_ROOT/BENCH_serve.json" \
    || { echo "loadgen smoke FAILED: no clean scraper-present run in BENCH_serve.json" >&2; exit 1; }
# belt and braces on top of loadgen's own socket bails (zero errors on
# both TCP runs, zero sheds on the clean run, ≥1 rejection on the
# overload run): the artifact must carry both tagged entries, and the
# front-door process must have *survived* the overload — a crashed or
# panicked server is a failure even if the clients limped through
grep -q '"socket":true' "$REPO_ROOT/BENCH_serve.json" \
    || { echo "socket smoke FAILED: no socket entry in BENCH_serve.json" >&2; exit 1; }
grep -q '"overload":true' "$REPO_ROOT/BENCH_serve.json" \
    || { echo "socket smoke FAILED: no overload entry in BENCH_serve.json" >&2; exit 1; }
kill -0 "$FRONT_PID" 2>/dev/null \
    || { echo "socket smoke FAILED: serve --listen died under load" >&2; cat "$FRONT_LOG" >&2; exit 1; }
grep -q "socket self-probe OK" "$FRONT_LOG" \
    || { echo "socket smoke FAILED: the server's own socket self-probe did not pass" >&2; cat "$FRONT_LOG" >&2; exit 1; }
kill "$FRONT_PID" 2>/dev/null || true
wait "$FRONT_PID" 2>/dev/null || true
rm -f "$FRONT_LOG"
echo "socket smoke OK — real-TCP entries measured through a live front door"

echo
echo "== train smoke (BENCH_train.json) =="
# The train-smoke scenario (see `switchback train --list`) presets the
# small dims and implies --assert-improves: the command fails unless
# every kind's loss strictly decreased over the run.
"$BIN" train train-smoke \
    --steps "$TRAIN_STEPS" \
    --kinds switchback,standard \
    --out "$REPO_ROOT/BENCH_train.json"

echo
echo "== gemm kernel shootout (BENCH_gemm.json) =="
# fig4 emits the quant-fraction artifact first; gemm_roofline embeds it
# so the benchdiff gate reads one file.  --quick times exactly the
# committed-baseline shape set (benchmarks/BENCH_gemm.baseline.json).
cargo bench --bench fig4_quant_fraction -- --quick \
    --out "$REPO_ROOT/.bench_gemm_quant.json"
cargo bench --bench gemm_roofline -- --quick \
    --out "$REPO_ROOT/BENCH_gemm.json" \
    --quant "$REPO_ROOT/.bench_gemm_quant.json"
grep -q '"bench":"gemm_kernels"' "$REPO_ROOT/BENCH_gemm.json" \
    || { echo "gemm smoke FAILED: BENCH_gemm.json is not a gemm_kernels artifact" >&2; exit 1; }
grep -q '"quant_fraction":' "$REPO_ROOT/BENCH_gemm.json" \
    || { echo "gemm smoke FAILED: quant-fraction block was not embedded" >&2; exit 1; }
rm -f "$REPO_ROOT/.bench_gemm_quant.json"

echo
echo "== ckpt pipeline: sharded async train → watcher promotes v2 snapshots mid-traffic → eval (BENCH_ckpt.json) =="
CKPT_PIPE="$REPO_ROOT/ckpts_verify_pipeline"
rm -rf "$CKPT_PIPE"
# hard-fails internally on: round-trip mismatch, a sharded async snapshot
# that is not bit-identical to the synchronous v1 save of the same step,
# dropped requests during the watcher-driven promotions, a promoted
# (instead of canary-rejected) drift injection, a quarantined staging
# hand-off, or serve/train encode divergence
# the pipeline runs backgrounded with its telemetry plane armed; a
# follower probe watches /readyz flip from the train phase to the serve
# phase (the engine-slot handover) while the scenario is still running —
# the live-observability proof the tier-1 tests can't give
PIPE_LOG="$REPO_ROOT/.verify_telemetry_pipeline.log"
rm -f "$PIPE_LOG"
"$BIN" pipeline \
    --steps "$PIPE_STEPS" \
    --requests "$PIPE_REQUESTS" \
    --ckpt-dir "$CKPT_PIPE" \
    --ckpt-shards 4 \
    --telemetry-addr 127.0.0.1:0 \
    --out "$REPO_ROOT/BENCH_ckpt.json" \
    --trace-out "$REPO_ROOT/BENCH_pipeline.trace.json" \
    --quiet >"$PIPE_LOG" 2>&1 &
PIPE_PID=$!
PIPE_URL=""
for _ in $(seq 1 100); do
    PIPE_URL="$(sed -n 's/^telemetry: listening on //p' "$PIPE_LOG" | head -n 1)"
    [[ -n "$PIPE_URL" ]] && break
    sleep 0.1
done
[[ -n "$PIPE_URL" ]] || {
    echo "pipeline smoke FAILED: pipeline never printed the telemetry address" >&2
    cat "$PIPE_LOG" >&2
    kill "$PIPE_PID" 2>/dev/null || true
    exit 1
}
# the follower: poll until the serve phase is visible on the wire (the
# train phase answers "phase":"train" first, so a match proves the
# handover happened mid-run), then confirm the generation detail rides
# along on the same verdict
"$BIN" probe "$PIPE_URL/readyz" --expect '"phase":"serve"' --follow 600 --every 100 \
    || { echo "pipeline smoke FAILED: /readyz never reached the serve phase" >&2; cat "$PIPE_LOG" >&2; exit 1; }
"$BIN" probe "$PIPE_URL/readyz" --expect '"generation":' --follow 50 --every 100 \
    || { echo "pipeline smoke FAILED: serve-phase /readyz carries no generation" >&2; cat "$PIPE_LOG" >&2; exit 1; }
wait "$PIPE_PID" \
    || { echo "pipeline smoke FAILED: pipeline exited nonzero" >&2; cat "$PIPE_LOG" >&2; exit 1; }
cat "$PIPE_LOG"
rm -f "$PIPE_LOG"
# belt and braces on top of the command's own asserts: the artifact must
# record ≥3 watcher promotions, the injected-drift rejection, no
# rollbacks/quarantines, zero dropped requests, and the sharded snapshot
# invariants (4 shards, bit-identical to the sync save)
# note the trailing comma in each pattern: it pins the exact value
# (":3" alone would also match 30)
grep -q '"standby_promotions":3,' "$REPO_ROOT/BENCH_ckpt.json" \
    || { echo "pipeline smoke FAILED: expected exactly 3 watcher promotions" >&2; exit 1; }
grep -q '"standby_rejects":1,' "$REPO_ROOT/BENCH_ckpt.json" \
    || { echo "pipeline smoke FAILED: drift injection was not rejected exactly once" >&2; exit 1; }
grep -q '"standby_rollbacks":0,' "$REPO_ROOT/BENCH_ckpt.json" \
    || { echo "pipeline smoke FAILED: unexpected rollback" >&2; exit 1; }
grep -q '"standby_quarantines":0,' "$REPO_ROOT/BENCH_ckpt.json" \
    || { echo "pipeline smoke FAILED: a staged snapshot was quarantined" >&2; exit 1; }
grep -q '"dropped_requests":0,' "$REPO_ROOT/BENCH_ckpt.json" \
    || { echo "pipeline smoke FAILED: dropped requests during promotions" >&2; exit 1; }
grep -q '"ckpt_shards":4,' "$REPO_ROOT/BENCH_ckpt.json" \
    || { echo "pipeline smoke FAILED: snapshots were not sharded 4 ways" >&2; exit 1; }
grep -q '"sharded_bit_identical":true,' "$REPO_ROOT/BENCH_ckpt.json" \
    || { echo "pipeline smoke FAILED: sharded async snapshot != sync v1 save" >&2; exit 1; }

echo
echo "== trace smoke: pipeline span dump → Perfetto export + span-time table =="
# the pipeline dump covers train + ckpt + serve spans end to end; the
# export must be loadable Chrome trace-event JSON (CI uploads it as the
# per-PR profiling artifact)
grep -q '"format":"switchback-trace"' "$REPO_ROOT/BENCH_pipeline.trace.json" \
    || { echo "trace smoke FAILED: pipeline wrote no span dump" >&2; exit 1; }
"$BIN" trace export "$REPO_ROOT/BENCH_pipeline.trace.json" \
    --out "$REPO_ROOT/BENCH_pipeline.perfetto.json"
grep -q '"traceEvents"' "$REPO_ROOT/BENCH_pipeline.perfetto.json" \
    || { echo "trace smoke FAILED: export is not Chrome trace-event JSON" >&2; exit 1; }
for span in train.step ckpt.shard_write serve.batch; do
    grep -q "\"$span\"" "$REPO_ROOT/BENCH_pipeline.trace.json" \
        || { echo "trace smoke FAILED: no $span spans in the pipeline dump" >&2; exit 1; }
done
"$BIN" trace top "$REPO_ROOT/BENCH_pipeline.trace.json"
echo "trace smoke OK — pipeline dump exported to BENCH_pipeline.perfetto.json"

echo
echo "== flight-recorder smoke: spiky adamw train → forensic dump + lead-lag =="
FLIGHT="$REPO_ROOT/.verify_flight.json"
FLIGHT_BENCH="$REPO_ROOT/.bench_flight_smoke.json"
rm -f "$FLIGHT"
# AdamW under the stuck-in-the-past shift schedule is the paper's spike
# reproducer; the recorder must dump iff the rollback guard or the
# post-hoc loss-spike detector fired (the run's own JSON says which)
"$BIN" train --kinds standard --optimizers adamw \
    --steps "$TRAIN_STEPS" --with-shifts --rollback-on-spike \
    --eval-per-concept 0 \
    --flight-out "$FLIGHT" --flight-window 32 \
    --out "$FLIGHT_BENCH" --quiet
if [ -f "$FLIGHT" ]; then
    grep -q '"format":"switchback-flight"' "$FLIGHT" \
        || { echo "flight smoke FAILED: dump is not flight-format JSON" >&2; exit 1; }
    grep -q '"under_estimation_ratio"' "$FLIGHT" \
        || { echo "flight smoke FAILED: no g²/v under-estimation probes in the dump" >&2; exit 1; }
    "$BIN" trace spikes "$FLIGHT" | grep -q "loss spikes follow an RMS spike" \
        || { echo "flight smoke FAILED: trace spikes lead-lag summary missing" >&2; exit 1; }
    echo "flight smoke OK — forensic dump written and analyzable"
else
    # no dump is only legitimate when nothing fired: spike ⇒ dump
    grep -q '"loss_spikes":0,' "$FLIGHT_BENCH" \
        || { echo "flight smoke FAILED: run spiked but wrote no flight dump" >&2; exit 1; }
    grep -q '"rollbacks":0,' "$FLIGHT_BENCH" \
        || { echo "flight smoke FAILED: guard fired but wrote no flight dump" >&2; exit 1; }
    echo "flight smoke OK — no spike at $TRAIN_STEPS steps, recorder stayed quiet"
fi
rm -f "$FLIGHT" "$FLIGHT_BENCH"

echo
echo "== standby smoke: sharded async train → watcher promotes the newer v2 snapshot =="
CKPT_STANDBY="$REPO_ROOT/ckpts_verify_standby"
rm -rf "$CKPT_STANDBY"
# two sharded snapshots written by the background saver (steps 10 and
# 20); serve boots the older shard *directory* with the watcher pointed
# at the same directory — the smoke waits for (and asserts) the
# canary-validated promotion of the sharded step-20 snapshot, then the
# usual probe/cache checks run on the promoted generation
"$BIN" train --kind switchback --steps 20 \
    --ckpt-every 10 --ckpt-dir "$CKPT_STANDBY" --eval-per-concept 0 \
    --ckpt-shards 4 --ckpt-async \
    --out "$REPO_ROOT/.bench_standby_smoke.json" -q
STANDBY_OUT="$("$BIN" serve --kind switchback \
    --weights "$CKPT_STANDBY/ckpt-00000010.sbck" \
    --watch-dir "$CKPT_STANDBY" --standby)"
echo "$STANDBY_OUT"
echo "$STANDBY_OUT" | grep -q "standby: promoted to generation 1" \
    || { echo "standby smoke FAILED: watcher did not promote the newer snapshot" >&2; exit 1; }
echo "$STANDBY_OUT" | grep -q "serve smoke OK" \
    || { echo "standby smoke FAILED: serve probes failed after promotion" >&2; exit 1; }
echo "standby smoke OK — watcher promoted the newer sharded snapshot under canary validation"
rm -rf "$CKPT_STANDBY" "$REPO_ROOT/.bench_standby_smoke.json"

echo
echo "== ckpt resume smoke: interrupted + resumed == uninterrupted (v1 sync vs v2 async) =="
CKPT_A="$REPO_ROOT/ckpts_verify_a"
CKPT_B="$REPO_ROOT/ckpts_verify_b"
rm -rf "$CKPT_A" "$CKPT_B"
# one 40-step run snapshotting v1 single files at 20/40, then a second
# trainer resumed from the step-20 snapshot writing *sharded async* (v2)
# snapshots; the v1 and v2 step-40 snapshots must be bit-identical —
# this greps the cross-version + background-save identity through the
# CLI surface (`ckpt diff` over a file and a shard directory)
"$BIN" train --kind switchback --steps 40 \
    --ckpt-every 20 --ckpt-dir "$CKPT_A" --eval-per-concept 0 \
    --out "$REPO_ROOT/.bench_ckpt_smoke_a.json" -q
"$BIN" train --resume "$CKPT_A/ckpt-00000020.sbck" \
    --ckpt-every 20 --ckpt-dir "$CKPT_B" --eval-per-concept 0 \
    --ckpt-shards 4 --ckpt-async \
    --out "$REPO_ROOT/.bench_ckpt_smoke_b.json" -q
"$BIN" ckpt inspect "$CKPT_B/ckpt-00000040.sbck"
DIFF_OUT="$("$BIN" ckpt diff "$CKPT_A/ckpt-00000040.sbck" "$CKPT_B/ckpt-00000040.sbck")"
echo "$DIFF_OUT"
echo "$DIFF_OUT" | grep -q "parameters: bit-identical" \
    || { echo "resume smoke FAILED: resumed weights differ" >&2; exit 1; }
echo "$DIFF_OUT" | grep -q "state identical" \
    || { echo "resume smoke FAILED: resumed optimizer state differs" >&2; exit 1; }
echo "$DIFF_OUT" | grep -q "cursor identical" \
    || { echo "resume smoke FAILED: resumed data cursor differs" >&2; exit 1; }
echo "resume smoke OK — interrupted+resumed run is bit-identical"
rm -rf "$CKPT_A" "$CKPT_B" "$CKPT_PIPE" \
    "$REPO_ROOT/.bench_ckpt_smoke_a.json" "$REPO_ROOT/.bench_ckpt_smoke_b.json"

echo
echo "verify OK — wrote $REPO_ROOT/BENCH_serve.json + $REPO_ROOT/BENCH_train.json + $REPO_ROOT/BENCH_ckpt.json + $REPO_ROOT/BENCH_gemm.json + $REPO_ROOT/BENCH_lint.json + $REPO_ROOT/BENCH_metrics.scrape.prom"
