#!/usr/bin/env bash
# Tier-1 verification + serve smoke + perf-trajectory artifact.
#
# Usage: scripts/verify.sh [--full]
#   default: tier-1 (build + tests) + serve smoke + a small loadgen run
#   --full : also the 10k-request acceptance sweep (slower)
#
# Emits BENCH_serve.json at the repo root so the serving perf trajectory
# (requests/sec, p99, hit rate per precision kind) is tracked across PRs
# (schema: EXPERIMENTS.md §Serve).

set -euo pipefail
cd "$(dirname "$0")/.."
REPO_ROOT="$(pwd)"

echo "== tier-1: cargo build --release && cargo test -q =="
cd rust
cargo build --release
cargo test -q

BIN=target/release/switchback

echo
echo "== serve smoke =="
"$BIN" serve --kind switchback --requests 64

echo
echo "== loadgen (BENCH_serve.json) =="
if [[ "${1:-}" == "--full" ]]; then
    REQUESTS=10000
    CONCURRENCY=32
else
    REQUESTS=1000
    CONCURRENCY=16
fi
"$BIN" loadgen \
    --requests "$REQUESTS" \
    --concurrency "$CONCURRENCY" \
    --kinds standard,switchback \
    --out "$REPO_ROOT/BENCH_serve.json"

echo
echo "verify OK — wrote $REPO_ROOT/BENCH_serve.json"
