#!/usr/bin/env bash
# Tier-1 verification + serve/train smokes + perf-trajectory artifacts.
#
# Usage: scripts/verify.sh [--full|--smoke]
#   default: tier-1 (build + tests) + serve smoke + a small loadgen run
#            + a 50-step native train smoke (loss must decrease)
#   --full : the 10k-request acceptance sweep + a 150-step train run
#   --smoke: skip `cargo test` (CI's bench-gate job runs after the
#            dedicated test job; the release build is incremental
#            against the restored cargo cache)
#
# Emits BENCH_serve.json and BENCH_train.json at the repo root so the
# serving and training perf trajectories are tracked across PRs (schemas:
# EXPERIMENTS.md §Serve / §Train).  scripts/check_bench.sh gates both
# against the committed baselines in benchmarks/.

set -euo pipefail
cd "$(dirname "$0")/.."
REPO_ROOT="$(pwd)"

MODE="${1:-}"
cd rust
if [[ "$MODE" == "--smoke" ]]; then
    echo "== build only (smoke mode): cargo build --release =="
    cargo build --release
else
    echo "== tier-1: cargo build --release && cargo test -q =="
    cargo build --release
    cargo test -q
fi

BIN=target/release/switchback

echo
echo "== serve smoke =="
"$BIN" serve --kind switchback --requests 64

echo
echo "== loadgen (BENCH_serve.json) =="
if [[ "$MODE" == "--full" ]]; then
    REQUESTS=10000
    CONCURRENCY=32
    TRAIN_STEPS=150
else
    REQUESTS=1000
    CONCURRENCY=16
    TRAIN_STEPS=50
fi
"$BIN" loadgen \
    --requests "$REQUESTS" \
    --concurrency "$CONCURRENCY" \
    --kinds standard,switchback \
    --out "$REPO_ROOT/BENCH_serve.json"

echo
echo "== train smoke (BENCH_train.json) =="
# The train-smoke scenario (see `switchback train --list`) presets the
# small dims and implies --assert-improves: the command fails unless
# every kind's loss strictly decreased over the run.
"$BIN" train train-smoke \
    --steps "$TRAIN_STEPS" \
    --kinds switchback,standard \
    --out "$REPO_ROOT/BENCH_train.json"

echo
echo "verify OK — wrote $REPO_ROOT/BENCH_serve.json + $REPO_ROOT/BENCH_train.json"
