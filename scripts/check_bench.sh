#!/usr/bin/env bash
# Bench-regression gate: compare freshly emitted BENCH_serve.json /
# BENCH_train.json against the committed baselines in benchmarks/ and fail
# on regressions beyond the tolerance (default 15%).
#
# The comparison itself lives in the binary (`switchback benchdiff`,
# rust/src/util/regression.rs) so it is unit-tested and reuses the
# in-tree JSON parser.  Default mode gates only machine-portable
# quantities (the SwitchBack/Standard throughput + p99 ratios, and the
# train-path learning invariants); pass --strict when both files were
# measured on the same machine to also gate absolutes.
#
# Usage: scripts/check_bench.sh [--strict] [--tol 0.15]
#   Run scripts/verify.sh first (it emits both BENCH files), or any
#   equivalent `switchback loadgen` / `switchback train` invocation.
#
# Refreshing baselines after an intentional perf change:
#   cp BENCH_serve.json benchmarks/BENCH_serve.baseline.json
#   cp BENCH_train.json benchmarks/BENCH_train.baseline.json
#   cp BENCH_ckpt.json  benchmarks/BENCH_ckpt.baseline.json
#   cp BENCH_gemm.json  benchmarks/BENCH_gemm.baseline.json
#   cp BENCH_lint.json  benchmarks/BENCH_lint.baseline.json
#
# The BENCH_lint pair is gated with lint semantics, not tolerances: zero
# active findings, zero lock cycles, and suppression counters that may
# only shrink relative to the committed baseline.

set -euo pipefail
cd "$(dirname "$0")/.."

BIN=rust/target/release/switchback
if [[ ! -x "$BIN" ]]; then
    echo "check_bench: $BIN not built — run scripts/verify.sh first" >&2
    exit 1
fi

EXTRA_ARGS=("$@")
FAILED=0

check() {
    local baseline=$1 fresh=$2
    if [[ ! -f "$baseline" ]]; then
        echo "check_bench: missing baseline $baseline" >&2
        FAILED=1
        return
    fi
    if [[ ! -f "$fresh" ]]; then
        echo "check_bench: missing $fresh — run scripts/verify.sh first" >&2
        FAILED=1
        return
    fi
    echo "== benchdiff: $fresh vs $baseline =="
    if ! "$BIN" benchdiff "$baseline" "$fresh" "${EXTRA_ARGS[@]+"${EXTRA_ARGS[@]}"}"; then
        FAILED=1
    fi
}

check benchmarks/BENCH_serve.baseline.json BENCH_serve.json
check benchmarks/BENCH_train.baseline.json BENCH_train.json
check benchmarks/BENCH_ckpt.baseline.json BENCH_ckpt.json
check benchmarks/BENCH_gemm.baseline.json BENCH_gemm.json
check_lint() {
    local baseline=benchmarks/BENCH_lint.baseline.json fresh=BENCH_lint.json
    if [[ ! -f "$baseline" || ! -f "$fresh" ]]; then
        echo "check_bench: missing $baseline or $fresh — run scripts/verify.sh first" >&2
        FAILED=1
        return
    fi
    echo "== benchdiff: $fresh vs $baseline (lint ledger) =="
    # no tolerance args: the lint comparator is exact by design
    if ! "$BIN" benchdiff "$baseline" "$fresh"; then
        FAILED=1
    fi
}
check_lint

if [[ "$FAILED" -ne 0 ]]; then
    echo "check_bench: FAILED (see regressions above)" >&2
    exit 1
fi
echo "check_bench OK — no regressions beyond tolerance"
