//! Stability lab: the stuck-in-the-past scenario end to end (paper §3.4).
//!
//! Trains the same model three times through a scheduled distribution shift
//! (the deterministic spike trigger — DESIGN.md substitutions):
//!   A. AdamW, β₂ = 0.999 (the PyTorch default — spikes)
//!   B. AdamW, β₂ = 0.95  (the blunt fix — slower learning)
//!   C. StableAdamW, β₂ = 0.999 (the paper's fix — update clipping)
//! then prints the RMS→loss-spike timeline and the Fig 9/10-shaped verdict.
//!
//! ```
//! cargo run --release --example stability_lab -- [steps]
//! ```

use switchback::config::{OptimizerKind, TrainConfig};
use switchback::coordinator::Trainer;
use switchback::data::Shift;
use switchback::runtime::Runtime;
use switchback::telemetry::{lead_lag_analysis, SpikeConfig};

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(260);
    let runtime = Runtime::cpu()?;
    let shifts = vec![
        Shift { at_step: steps * 55 / 100, image_gain: 6.0, remap_concepts: false },
        Shift { at_step: steps * 70 / 100, image_gain: 1.0 / 6.0, remap_concepts: true },
        Shift { at_step: steps * 85 / 100, image_gain: 8.0, remap_concepts: false },
    ];
    let spike_cfg = SpikeConfig { burn_in: steps / 8, ..Default::default() };

    let runs = [
        ("A: AdamW β2=0.999", OptimizerKind::Adamw, 0.999f32),
        ("B: AdamW β2=0.95 ", OptimizerKind::Adamw, 0.95),
        ("C: StableAdamW   ", OptimizerKind::StableAdamw, 0.999),
    ];
    let mut summaries = vec![];
    for (tag, opt, beta2) in runs {
        println!("\n=== {tag} ===");
        let mut cfg = TrainConfig::preset("highprec_tiny_b32", steps)
            .with_optimizer(opt, beta2);
        cfg.shifts = shifts.clone();
        let mut trainer = Trainer::new(&runtime, cfg)?;
        let res = trainer.run(false)?;
        let loss = res.loss_trace();
        let rms = res.sink.rms_trace(&res.probe_names.0);
        let report = lead_lag_analysis(&loss, &rms, &spike_cfg);
        println!("  {}", report.summary());
        for &t in report.loss_spikes.iter().take(3) {
            let t = t as usize;
            let lo = t.saturating_sub(9);
            print!("  spike @ {t}: loss ");
            for i in lo..(t + 2).min(loss.len()) {
                print!("{:6.3} ", loss[i]);
            }
            print!("\n             RMS  ");
            for i in lo..(t + 2).min(rms.len()) {
                print!("{:6.2} ", rms[i]);
            }
            println!();
        }
        let max_rms = rms.iter().fold(0.0f32, |m, &v| m.max(v));
        summaries.push((
            tag,
            report.total_loss_spikes,
            max_rms,
            res.tail_loss,
            res.zero_shot_acc.unwrap_or(f32::NAN),
        ));
    }

    println!("\n=== verdict (paper Fig 6/9/10 shape) ===");
    println!("  run                 spikes  max RMS_t  tail-loss    acc");
    for (tag, spikes, max_rms, tail, acc) in &summaries {
        println!(
            "  {tag}  {spikes:>4}   {max_rms:8.2}  {tail:9.4}  {:5.1}%",
            100.0 * acc
        );
    }
    println!("\n  expected: A spikes (RMS ≫ 1 precedes each); B calm but slower;");
    println!("  C calm at high β2 with the best accuracy — the paper's recommendation.");
    Ok(())
}
