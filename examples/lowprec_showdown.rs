//! Low-precision showdown: every linear-layer precision variant trained on
//! the same data, same init, same optimizer (the Fig 1/2 story in one run).
//!
//! Also demonstrates the native kernels: times one SwitchBack vs standard
//! vs LLM.int8() block step on the rust GEMM substrate (the Fig 3/13 story).
//!
//! ```
//! cargo run --release --example lowprec_showdown -- [steps]
//! ```

use switchback::config::TrainConfig;
use switchback::coordinator::Trainer;
use switchback::nn::{LinearKind, TransformerBlock};
use switchback::runtime::Runtime;
use switchback::tensor::{Matrix, Rng};
use switchback::util::bench;

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let runtime = Runtime::cpu()?;

    println!("=== accuracy: all precision variants, same init/data (tiny) ===");
    let variants = [
        "highprec",
        "switchback_int8",
        "llmint8",
        "fp8_tensorwise",
        "switchback_fp8",
    ];
    let mut rows = vec![];
    for v in variants {
        let cfg = TrainConfig::preset(&format!("{v}_tiny_b32"), steps);
        let mut trainer = Trainer::new(&runtime, cfg)?;
        let res = trainer.run(false)?;
        println!(
            "  {v:<18} tail-loss {:8.4}  acc {:5.1}%  {}",
            res.tail_loss,
            100.0 * res.zero_shot_acc.unwrap_or(f32::NAN),
            if res.diverged { "DIVERGED" } else { "" }
        );
        rows.push((v, res.tail_loss, res.zero_shot_acc.unwrap_or(f32::NAN)));
    }
    let base = rows.iter().find(|r| r.0 == "highprec").unwrap().2;
    println!("\n  Δacc vs highprec (paper: SwitchBack ≈ 0, LLM.int8 clearly negative):");
    for (v, _, acc) in &rows {
        if *v != "highprec" {
            println!("    {v:<18} {:+5.1}pp", 100.0 * (acc - base));
        }
    }

    println!("\n=== speed: one transformer-block train step on the native kernels ===");
    let (dim, seq, batch) = (512, 64, 8);
    let mut rng = Rng::seed(0);
    let x = Matrix::randn(batch * seq, dim, 0.5, &mut rng);
    for kind in [LinearKind::Standard, LinearKind::SwitchBack, LinearKind::LlmInt8] {
        let blk = TransformerBlock::new(dim, 8, seq, kind, &mut Rng::seed(1));
        let r = bench::bench(kind.label(), 8, || {
            let _ = blk.train_step_compute(&x);
        });
        bench::report(&r);
    }
    println!("  (paper Fig 4/13: SwitchBack beats the standard layer; LLM.int8 does not)");
    Ok(())
}
