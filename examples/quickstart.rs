//! Quickstart: the full three-layer stack in one minute.
//!
//! 1. loads the **Pallas-kernel** artifact (L1 int8 kernels, lowered through
//!    the L2 jax model to HLO text) on the PJRT CPU client,
//! 2. runs a handful of training steps with **StableAdamW** (L3, Algorithm 2),
//! 3. prints the loss and the per-tensor RMS_t telemetry the paper's
//!    stability analysis is built on.
//!
//! Run after `make artifacts && cargo build --release`:
//! ```
//! cargo run --release --example quickstart
//! ```

use switchback::config::{OptimizerKind, TrainConfig};
use switchback::coordinator::Trainer;
use switchback::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let runtime = Runtime::cpu()?;
    println!("PJRT platform: {}", runtime.platform());

    // The artifact whose linear layers run through real Pallas kernels
    // (interpret-mode): proves L1 → L2 → L3 composition.
    let mut cfg = TrainConfig::preset("switchback_int8_pallas_micro_b8", 30)
        .with_optimizer(OptimizerKind::StableAdamw, 0.99);
    cfg.lr = 1e-3;
    println!("training config: {}", cfg.to_json());

    let mut trainer = Trainer::new(&runtime, cfg)?;
    {
        let art = trainer.artifact();
        println!(
            "loaded {}: {} tensors / {} params (variant {})",
            art.manifest.name, art.manifest.n_tensors, art.manifest.n_params,
            art.manifest.variant,
        );
    }

    let res = trainer.run(true)?;
    println!("\nloss curve (every 5 steps):");
    for (i, l) in res.loss_trace().iter().enumerate() {
        if i % 5 == 0 {
            println!("  step {:>3}: {l:.4}", i + 1);
        }
    }
    let (pe, _) = &res.probe_names;
    let rms = res.sink.rms_trace(pe);
    println!(
        "\nRMS_t of the patch embedding ({pe}): first {:.2} last {:.2} max {:.2}",
        rms.first().unwrap_or(&1.0),
        rms.last().unwrap_or(&1.0),
        rms.iter().fold(0.0f32, |m, &v| m.max(v)),
    );
    println!("(RMS_t ≈ 1 means the AdamW second-moment estimator is healthy — §3.4)");
    Ok(())
}
