//! End-to-end driver: train a real CLIP model through the full system and
//! log the loss curve + zero-shot accuracy (the EXPERIMENTS.md §E2E run).
//!
//! All layers compose here: synthetic corpus (rust) → AOT'd jax model with
//! SwitchBack int8 linear layers (PJRT) → StableAdamW + telemetry (rust).
//!
//! ```
//! cargo run --release --example train_clip_e2e -- [size] [steps]
//!   size  ∈ {micro, tiny, small, base*}      (default small; *needs `make artifacts-large`)
//!   steps (default 300)
//! ```

use switchback::config::{OptimizerKind, TrainConfig};
use switchback::coordinator::Trainer;
use switchback::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size = args.first().map(String::as_str).unwrap_or("small");
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let artifact = match size {
        "base" => "switchback_int8_base_b16".to_string(),
        s => format!("switchback_int8_{s}_b32"),
    };

    let runtime = Runtime::cpu()?;
    println!("PJRT platform: {}", runtime.platform());
    let mut cfg = TrainConfig::preset(&artifact, steps)
        .with_optimizer(OptimizerKind::StableAdamw, 0.99);
    cfg.metrics_path = Some(format!("results/e2e/{size}_{steps}.jsonl"));
    println!("e2e config: {}", cfg.to_json());

    let mut trainer = Trainer::new(&runtime, cfg)?;
    let batch = {
        let art = trainer.artifact();
        println!(
            "model: {} — {} params, {} tensors, batch {}",
            art.manifest.name, art.manifest.n_params, art.manifest.n_tensors,
            art.manifest.batch,
        );
        art.manifest.batch
    };

    let t0 = std::time::Instant::now();
    let res = trainer.run(true)?;
    let mins = t0.elapsed().as_secs_f32() / 60.0;

    println!("\n=== loss curve (10 points) ===");
    let loss = res.loss_trace();
    let n = loss.len();
    for i in 0..10 {
        let idx = (i * n / 10).min(n - 1);
        println!("  step {:>5}: {:.4}", idx + 1, loss[idx]);
    }
    println!("  step {:>5}: {:.4}  (final)", n, loss[n - 1]);
    println!("\n=== summary ===");
    println!("  steps/s          : {:.2}", res.steps_per_sec);
    println!("  wall time        : {mins:.1} min");
    println!("  first loss       : {:.4}  (ln batch = {:.4})",
             loss[0], (batch as f32).ln());
    println!("  tail loss        : {:.4}", res.tail_loss);
    println!(
        "  zero-shot acc    : {}   (chance = {:.1}%)",
        res.zero_shot_acc
            .map(|a| format!("{:.1}%", 100.0 * a))
            .unwrap_or_else(|| "n/a".into()),
        100.0 / 64.0
    );
    println!("  diverged         : {}", res.diverged);
    println!("  metrics          : results/e2e/{size}_{steps}.jsonl");
    Ok(())
}
