"""Transformer towers (vision + text) with precision-pluggable linears.

Pre-norm ViT blocks, faithful to the paper's setup (§3.2):

* the patch embedding is a linear layer over pre-patchified input — the
  analogue of ``visual.conv1.weight`` (the layer whose stale second-moment
  estimator causes loss spikes, §3.4);
* a layer-norm sits after the patch embedding, before the transformer
  ("we add a layer-norm after the patch embedding", §3.2);
* optional zero-init **layer-scale** (eqs. (5)–(6)):
  ``x' = x + γ1 * attn(ln(x))``, ``x'' = x' + γ2 * mlp(ln(x'))``;
* optional **KQ layernorm** (the Fig 5 baseline that still diverges);
* every q/k/v/out/mlp projection routes through ``layers.apply_linear`` so
  the whole tower switches between highprec / SwitchBack / LLM.int8 / fp8.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .configs import ModelConfig


def _init_linear(key, out_dim, in_dim, std=None):
    std = std if std is not None else (2.0 / (in_dim + out_dim)) ** 0.5
    return jax.random.normal(key, (out_dim, in_dim), jnp.float32) * std


def init_block(key, cfg: ModelConfig):
    d, r = cfg.dim, cfg.mlp_ratio
    ks = jax.random.split(key, 6)
    p = {
        "ln1": {"g": jnp.ones(d), "b": jnp.zeros(d)},
        "attn": {
            "wq": _init_linear(ks[0], d, d),
            "wk": _init_linear(ks[1], d, d),
            "wv": _init_linear(ks[2], d, d),
            "wo": _init_linear(ks[3], d, d),
        },
        "ln2": {"g": jnp.ones(d), "b": jnp.zeros(d)},
        "mlp": {
            "w1": _init_linear(ks[4], r * d, d),
            "w2": _init_linear(ks[5], d, r * d),
        },
    }
    if cfg.kq_norm:
        p["kqn"] = {
            "gq": jnp.ones(d), "bq": jnp.zeros(d),
            "gk": jnp.ones(d), "bk": jnp.zeros(d),
        }
    if cfg.layer_scale:
        # Zero-init layer-scale: at init the whole tower is the identity,
        # which is what keeps feature magnitudes small (§2.3, Fig 5 right).
        p["ls1"] = jnp.zeros(d)
        p["ls2"] = jnp.zeros(d)
    return p


def attention(bp, x, heads: int, cfg: ModelConfig, causal: bool):
    """Multi-head self-attention.  Projections use the precision variant;
    the QKᵀ/softmax/AV core stays high precision (the paper replaces only
    the nn.Linear layers).  ``bp`` is the whole block param dict (so the
    optional KQ-layernorm params are visible)."""
    p = bp["attn"]
    B, S, d = x.shape
    hd = d // heads
    v = cfg.variant
    q = layers.apply_linear(v, x, p["wq"])
    k = layers.apply_linear(v, x, p["wk"])
    if cfg.kq_norm:
        kq = bp["kqn"]
        q = layers.layer_norm(q, kq["gq"], kq["bq"])
        k = layers.layer_norm(k, kq["gk"], kq["bk"])
    vv = layers.apply_linear(v, x, p["wv"])

    def split(t):
        return t.reshape(B, S, heads, hd).transpose(0, 2, 1, 3)

    q, k, vv = split(q), split(k), split(vv)
    scores = (q @ k.transpose(0, 1, 3, 2)) / (hd**0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), jnp.bool_))
        scores = jnp.where(mask[None, None], scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    out = (attn @ vv).transpose(0, 2, 1, 3).reshape(B, S, d)
    return layers.apply_linear(v, out, p["wo"])


def block_apply(p, x, cfg: ModelConfig, causal: bool):
    """One pre-norm block, with optional layer-scale (paper eqs. (5)–(6))."""
    h = attention(p, layers.layer_norm(x, p["ln1"]["g"], p["ln1"]["b"]),
                  cfg.heads, cfg, causal)
    if cfg.layer_scale:
        h = h * p["ls1"]
    x = x + h
    m = layers.apply_linear(
        cfg.variant, layers.layer_norm(x, p["ln2"]["g"], p["ln2"]["b"]),
        p["mlp"]["w1"])
    m = layers.gelu(m)
    m = layers.apply_linear(cfg.variant, m, p["mlp"]["w2"])
    if cfg.layer_scale:
        m = m * p["ls2"]
    return x + m


def init_vision_tower(key, cfg: ModelConfig):
    d = cfg.dim
    ks = jax.random.split(key, cfg.vision_blocks + 3)
    return {
        "patch_embed": _init_linear(ks[0], d, cfg.patch_dim),
        "ln_pre": {"g": jnp.ones(d), "b": jnp.zeros(d)},
        "pos": jax.random.normal(ks[1], (cfg.patches, d)) * 0.02,
        "blocks": [init_block(ks[2 + i], cfg) for i in range(cfg.vision_blocks)],
        "ln_post": {"g": jnp.ones(d), "b": jnp.zeros(d)},
        "proj": _init_linear(ks[-1], cfg.edim, d, std=d**-0.5),
    }


def init_text_tower(key, cfg: ModelConfig):
    d = cfg.dim
    ks = jax.random.split(key, cfg.text_blocks + 3)
    return {
        "tok_embed": jax.random.normal(ks[0], (cfg.vocab, d)) * 0.02,
        "pos": jax.random.normal(ks[1], (cfg.seq, d)) * 0.02,
        "blocks": [init_block(ks[2 + i], cfg) for i in range(cfg.text_blocks)],
        "ln_post": {"g": jnp.ones(d), "b": jnp.zeros(d)},
        "proj": _init_linear(ks[-1], cfg.edim, d, std=d**-0.5),
    }


def vision_forward(p, images, cfg: ModelConfig):
    """``images [B, patches, patch_dim]`` → (embedding [B, edim],
    per-block mean-|feature| magnitudes [vision_blocks])."""
    x = layers.apply_linear(cfg.variant, images, p["patch_embed"])
    x = layers.layer_norm(x, p["ln_pre"]["g"], p["ln_pre"]["b"])
    x = x + p["pos"][None]
    mags = []
    for bp in p["blocks"]:
        x = block_apply(bp, x, cfg, causal=False)
        # E[abs(x_k)] — the Fig 5 (right) / Fig 14 probe.
        mags.append(jnp.mean(jnp.abs(x)))
    x = layers.layer_norm(x, p["ln_post"]["g"], p["ln_post"]["b"])
    pooled = jnp.mean(x, axis=1)
    emb = layers.apply_linear(cfg.variant, pooled, p["proj"])
    return emb, jnp.stack(mags)


def text_forward(p, tokens, cfg: ModelConfig):
    """``tokens [B, seq] int32`` → (embedding [B, edim], magnitudes)."""
    x = jnp.take(p["tok_embed"], tokens, axis=0) + p["pos"][None]
    mags = []
    for bp in p["blocks"]:
        x = block_apply(bp, x, cfg, causal=True)
        mags.append(jnp.mean(jnp.abs(x)))
    x = layers.layer_norm(x, p["ln_post"]["g"], p["ln_post"]["b"])
    pooled = jnp.mean(x, axis=1)
    emb = layers.apply_linear(cfg.variant, pooled, p["proj"])
    return emb, jnp.stack(mags)
