"""L2 building blocks: precision-pluggable linear layers via ``jax.custom_vjp``.

Every linear layer in the transformer (k/q/v/out projections + MLP, i.e.
>90% of compute) is routed through one of these variants; everything else
(layernorm, softmax, residuals) stays in high precision, exactly as in the
paper (§1).

Variants (paper §2.2):

``highprec``          standard matmul fwd/bwd — the bfloat16-baseline stand-in
                      (CPU PJRT computes f32; see DESIGN.md substitutions).
``switchback_int8``   Algorithm 1: int8 fwd + dgrad (row-wise X/G, tensor-wise
                      W), **high-precision wgrad** (inner dim = batch×seq).
``switchbackq_int8``  Algorithm 4: row/column-wise weight quant instead of
                      tensor-wise; wgrad still high precision.
``llmint8``           LLM.int8()-style: all THREE matmuls int8 — the baseline
                      that loses 5.9pp at ViT-Huge (Fig 1 left).
``fp8_tensorwise``    §2.3 baseline: all matmuls in simulated fp8 (exact E4M3
                      values) with tensor-wise scaling — diverges at scale
                      unless feature magnitudes are controlled (Fig 1 right,
                      Fig 5).
``switchback_fp8``    SwitchBack with fp8 quantization instead of int8.

Each variant has two implementations with identical semantics:
the pure-jnp path (default — fast under CPU-interpreted AOT) and the Pallas
kernel path (``use_kernels=True`` — proves L1→L2→L3 composition; pytest
asserts the two agree).  The custom VJP makes jax.grad produce exactly the
quantized backward of Algorithm 1 regardless of path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import fp8, quant, ref, switchback


def _as2d(x):
    """Collapse leading dims: linear layers see [batch*seq, features]."""
    return x.reshape(-1, x.shape[-1])


# ---------------------------------------------------------------------------
# highprec
# ---------------------------------------------------------------------------


def linear_highprec(x, w):
    """Standard full-precision linear: ``Y = X Wᵀ`` with the usual VJP."""
    return x @ w.T


# ---------------------------------------------------------------------------
# SwitchBack (int8)  — Algorithm 1
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _switchback_int8(x, w, use_kernels=False):
    if use_kernels:
        return switchback.switchback_fwd(x, w)
    return ref.switchback_fwd_ref(x, w)


def _switchback_int8_fwd(x, w, use_kernels):
    return _switchback_int8(x, w, use_kernels), (x, w)


def _switchback_int8_bwd(use_kernels, res, g):
    x, w = res
    if use_kernels:
        dx = switchback.switchback_dgrad(g, w)
        dw = switchback.switchback_wgrad(g, x)
    else:
        dx = ref.switchback_dgrad_ref(g, w)
        dw = ref.switchback_wgrad_ref(g, x)
    return dx, dw


_switchback_int8.defvjp(_switchback_int8_fwd, _switchback_int8_bwd)


def linear_switchback_int8(x, w, use_kernels=False):
    """SwitchBack int8 linear (Algorithm 1)."""
    return _switchback_int8(x, w, use_kernels)


# ---------------------------------------------------------------------------
# SwitchBackQ (int8, row/col-wise weights) — Algorithm 4
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _switchbackq_int8(x, w):
    return ref.llmint8_fwd_ref(x, w)


def _switchbackq_fwd(x, w):
    return _switchbackq_int8(x, w), (x, w)


def _switchbackq_bwd(res, g):
    x, w = res
    dx = ref.llmint8_dgrad_ref(g, w)
    dw = ref.switchback_wgrad_ref(g, x)  # wgrad stays high precision
    return dx, dw


_switchbackq_int8.defvjp(_switchbackq_fwd, _switchbackq_bwd)


def linear_switchbackq_int8(x, w):
    """SwitchBackQ: row-/column-wise weight quant, high-precision wgrad."""
    return _switchbackq_int8(x, w)


# ---------------------------------------------------------------------------
# LLM.int8()-style — ALL matmuls int8 (the paper's failing baseline)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _llmint8(x, w):
    return ref.llmint8_fwd_ref(x, w)


def _llmint8_fwd(x, w):
    return _llmint8(x, w), (x, w)


def _llmint8_bwd(res, g):
    x, w = res
    dx = ref.llmint8_dgrad_ref(g, w)
    dw = ref.llmint8_wgrad_ref(g, x)  # int8 wgrad: the noisy one
    return dx, dw


_llmint8.defvjp(_llmint8_fwd, _llmint8_bwd)


def linear_llmint8(x, w):
    """LLM.int8()-equivalent: int8 for fwd, dgrad AND wgrad (Fig 1-left
    baseline; Appendix C explains why the wgrad noise sinks CLIP training)."""
    return _llmint8(x, w)


# ---------------------------------------------------------------------------
# fp8 tensor-wise (§2.3 baseline) and SwitchBack-fp8
# ---------------------------------------------------------------------------


def _fp8_mm_tensorwise(a, b_t, fmt):
    """Tensor-wise fp8 matmul a @ b_tᵀ (both operands fp8-rounded)."""
    av, sa = fp8.fp8_tensorwise_quant_ref(a, fmt)
    bv, sb = fp8.fp8_tensorwise_quant_ref(b_t, fmt)
    return fp8.fp8_matmul_dequant_ref(av, bv, sa, sb, fmt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _fp8_tensorwise(x, w, fmt_name="e4m3"):
    return _fp8_mm_tensorwise(x, w, fp8.FORMATS[fmt_name])


def _fp8_tw_fwd(x, w, fmt_name):
    return _fp8_tensorwise(x, w, fmt_name), (x, w)


def _fp8_tw_bwd(fmt_name, res, g):
    x, w = res
    fmt = fp8.FORMATS[fmt_name]
    dx = _fp8_mm_tensorwise(g, w.T, fmt)
    dw = _fp8_mm_tensorwise(g.T, x.T, fmt)
    return dx, dw


_fp8_tensorwise.defvjp(_fp8_tw_fwd, _fp8_tw_bwd)


def linear_fp8_tensorwise(x, w, fmt_name="e4m3"):
    """fp8 with tensor-wise quantization for inputs, weights AND gradients —
    the straightforward baseline that diverges at >420M scale (Fig 1 right)."""
    return _fp8_tensorwise(x, w, fmt_name)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _switchback_fp8(x, w, fmt_name="e4m3"):
    fmt = fp8.FORMATS[fmt_name]
    xv, sx = fp8.fp8_rowwise_quant_ref(x, fmt)
    wv, sw = fp8.fp8_tensorwise_quant_ref(w, fmt)
    return fp8.fp8_matmul_dequant_ref(xv, wv, sx, sw, fmt)


def _switchback_fp8_fwd(x, w, fmt_name):
    return _switchback_fp8(x, w, fmt_name), (x, w)


def _switchback_fp8_bwd(fmt_name, res, g):
    x, w = res
    fmt = fp8.FORMATS[fmt_name]
    gv, sg = fp8.fp8_rowwise_quant_ref(g, fmt)
    wv, sw = fp8.fp8_tensorwise_quant_ref(w.T, fmt)
    dx = fp8.fp8_matmul_dequant_ref(gv, wv, sg, sw, fmt)
    dw = g.T @ x  # high-precision wgrad, as in int8 SwitchBack
    return dx, dw


_switchback_fp8.defvjp(_switchback_fp8_fwd, _switchback_fp8_bwd)


def linear_switchback_fp8(x, w, fmt_name="e4m3"):
    """SwitchBack with fp8 (row-wise X/G, tensor-wise W, high-prec wgrad)."""
    return _switchback_fp8(x, w, fmt_name)


# ---------------------------------------------------------------------------
# Variant registry
# ---------------------------------------------------------------------------

VARIANTS = {
    "highprec": lambda x, w: linear_highprec(x, w),
    "switchback_int8": lambda x, w: linear_switchback_int8(x, w, False),
    "switchback_int8_pallas": lambda x, w: linear_switchback_int8(x, w, True),
    "switchbackq_int8": linear_switchbackq_int8,
    "llmint8": linear_llmint8,
    "fp8_tensorwise": lambda x, w: linear_fp8_tensorwise(x, w, "e4m3"),
    "fp8_tensorwise_e5m2": lambda x, w: linear_fp8_tensorwise(x, w, "e5m2"),
    "switchback_fp8": lambda x, w: linear_switchback_fp8(x, w, "e4m3"),
}


def apply_linear(variant: str, x, w):
    """Apply variant linear over arbitrary leading dims: ``[..., n] → [..., m]``."""
    fn = VARIANTS[variant]
    y = fn(_as2d(x), w)
    return y.reshape(*x.shape[:-1], w.shape[0])


# ---------------------------------------------------------------------------
# Non-linear layers (always high precision, as in the paper)
# ---------------------------------------------------------------------------


def layer_norm(x, gamma, beta, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def gelu(x):
    return jax.nn.gelu(x, approximate=True)
