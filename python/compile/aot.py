"""AOT pipeline: lower the L2 model to HLO text + manifests for rust.

For every entry in ``configs.BUILDS`` this emits into ``artifacts/``:

* ``<name>.hlo.txt``         — train-step HLO: ``(p_0..p_N, images, tokens)
                               → (loss, block_mags, g_0..g_N)``
* ``<name>.encode.hlo.txt``  — eval HLO: ``→ (image_embs, text_embs)``
* ``<name>.manifest.json``   — tensor names/shapes/offsets, optimizer
                               metadata (decay mask, tensor kinds), input
                               shapes, output layout, init specs
* ``<name>.params.bin``      — raw little-endian f32 initial parameters
                               (seed 0), concatenated in manifest order

HLO **text** is the interchange format (not ``.serialize()``): jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Python runs only here — never on the training path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .configs import BUILDS, Build, make_config


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _init_spec(name: str, leaf) -> str:
    """Describe how to re-initialize this tensor for a fresh seed (rust side)."""
    arr = np.asarray(leaf)
    if arr.ndim == 0:
        return f"const:{float(arr):.6f}"
    if np.all(arr == 0):
        return "zeros"
    if np.all(arr == 1):
        return "ones"
    return f"normal:{float(arr.std()):.6g}"


def build_one(build: Build, outdir: str, check: bool = False) -> dict:
    cfg = make_config(build.size, variant=build.variant,
                      layer_scale=build.layer_scale, kq_norm=build.kq_norm)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    leaves, names, treedef = model.flatten_params(params)
    n = len(leaves)
    B = build.batch
    img_spec = jax.ShapeDtypeStruct((B, cfg.patches, cfg.patch_dim), jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((B, cfg.seq), jnp.int32)
    leaf_specs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]

    def train_fn(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:n])
        loss, mags, grads = model.loss_and_grads(p, args[n], args[n + 1], cfg)
        return (loss, mags, *jax.tree_util.tree_leaves(grads))

    def encode_fn(*args):
        p = jax.tree_util.tree_unflatten(treedef, args[:n])
        return model.encode(p, args[n], args[n + 1], cfg)

    name = build.name
    lowered = jax.jit(train_fn, keep_unused=True).lower(*leaf_specs, img_spec, tok_spec)
    hlo_path = os.path.join(outdir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(to_hlo_text(lowered))

    encode_rel = None
    if build.with_encode:
        enc_lowered = jax.jit(encode_fn, keep_unused=True).lower(*leaf_specs, img_spec, tok_spec)
        encode_rel = f"{name}.encode.hlo.txt"
        with open(os.path.join(outdir, encode_rel), "w") as f:
            f.write(to_hlo_text(enc_lowered))

    # Initial parameters (seed 0), concatenated f32 little-endian.
    flat = np.concatenate([np.asarray(l, np.float32).ravel() for l in leaves])
    bin_rel = f"{name}.params.bin"
    flat.tofile(os.path.join(outdir, bin_rel))

    offset = 0
    tensors = []
    for nm, leaf in zip(names, leaves):
        arr = np.asarray(leaf)
        meta = model.param_metadata(nm, arr.shape)
        tensors.append({
            "name": nm,
            "shape": list(arr.shape),
            "numel": int(arr.size),
            "offset": offset,
            "decay": meta["decay"],
            "kind": meta["kind"],
            "init": _init_spec(nm, leaf),
        })
        offset += int(arr.size)

    manifest = {
        "name": name,
        "size": build.size,
        "variant": build.variant,
        "batch": B,
        "config": {
            "dim": cfg.dim, "vision_blocks": cfg.vision_blocks,
            "text_blocks": cfg.text_blocks, "heads": cfg.heads,
            "patches": cfg.patches, "patch_dim": cfg.patch_dim,
            "seq": cfg.seq, "vocab": cfg.vocab, "embed_dim": cfg.edim,
            "layer_scale": cfg.layer_scale, "kq_norm": cfg.kq_norm,
        },
        "n_tensors": n,
        "n_params": int(flat.size),
        "inputs": {
            "images": [B, cfg.patches, cfg.patch_dim],
            "tokens": [B, cfg.seq],
        },
        "outputs": {
            "loss": 0, "mags": 1, "grads_start": 2,
            "n_mags": cfg.vision_blocks + cfg.text_blocks,
        },
        "hlo": f"{name}.hlo.txt",
        "encode_hlo": encode_rel,
        "params_bin": bin_rel,
        "tensors": tensors,
    }
    with open(os.path.join(outdir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if check:
        # Golden step: deterministic batch, executed by jax, recorded so the
        # rust integration test can verify the runtime end-to-end.
        imgs = np.sin(np.arange(B * cfg.patches * cfg.patch_dim,
                                dtype=np.float32)).reshape(
            B, cfg.patches, cfg.patch_dim)
        toks = (np.arange(B * cfg.seq, dtype=np.int32) % cfg.vocab).reshape(
            B, cfg.seq)
        out = jax.jit(train_fn)(*leaves, jnp.asarray(imgs), jnp.asarray(toks))
        golden = {
            "loss": float(out[0]),
            "mags": [float(v) for v in np.asarray(out[1])],
            "grad0_l2": float(np.linalg.norm(np.asarray(out[2]))),
        }
        with open(os.path.join(outdir, f"{name}.golden.json"), "w") as f:
            json.dump(golden, f, indent=1)

    return manifest


def write_quant_golden(outdir: str) -> None:
    """Golden vectors for the rust `quant` mirror: a deterministic matrix and
    its row-wise / tensor-wise / fp8 quantizations from the jnp oracles.
    `rust/tests/golden.rs` asserts bit-for-bit agreement."""
    from .kernels import fp8 as fp8mod
    from .kernels import ref

    rows, cols = 13, 37
    x = np.sin(0.7 * np.arange(rows * cols, dtype=np.float32) ** 1.1).reshape(
        rows, cols) * 3.0
    rc, rs = ref.rowwise_quant_ref(x)
    tc, ts = ref.tensorwise_quant_ref(x)
    fp8_vals = fp8mod.fp8_round_ref(jnp.asarray(x.ravel()[:64]), fp8mod.E4M3)
    fp8_e5 = fp8mod.fp8_round_ref(jnp.asarray(x.ravel()[:64]) * 100.0, fp8mod.E5M2)
    golden = {
        "rows": rows,
        "cols": cols,
        "x": [float(v) for v in x.ravel()],
        "row_codes": [int(v) for v in np.asarray(rc).ravel()],
        "row_state": [float(v) for v in np.asarray(rs)],
        "tensor_codes": [int(v) for v in np.asarray(tc).ravel()],
        "tensor_state": float(ts),
        "fp8_e4m3": [float(v) for v in np.asarray(fp8_vals)],
        "fp8_e5m2_x100": [float(v) for v in np.asarray(fp8_e5)],
    }
    with open(os.path.join(outdir, "quant_golden.json"), "w") as f:
        json.dump(golden, f)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters on build names")
    ap.add_argument("--large", action="store_true",
                    help="also build the base/e2e100m artifacts")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    builds = list(BUILDS)
    if not args.large:
        builds = [b for b in builds if b.size not in ("base", "e2e100m")]
    if args.only:
        pats = args.only.split(",")
        builds = [b for b in builds if any(p in b.name for p in pats)]
    if args.list:
        for b in builds:
            print(b.name)
        return

    os.makedirs(args.out, exist_ok=True)
    write_quant_golden(args.out)
    index = []
    for i, b in enumerate(builds):
        print(f"[{i + 1}/{len(builds)}] lowering {b.name} ...", flush=True)
        m = build_one(b, args.out, check=(b.size == "micro"
                                          and b.variant == "highprec"
                                          and b.batch == 32))
        index.append({"name": m["name"], "size": m["size"],
                      "variant": m["variant"], "batch": m["batch"],
                      "n_params": m["n_params"]})
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"wrote {len(index)} artifact sets to {args.out}")


if __name__ == "__main__":
    main()
