"""Build-time Python for the SwitchBack + StableAdamW reproduction.

L1: ``kernels/`` — Pallas kernels + pure-jnp oracles.
L2: ``layers`` / ``vit`` / ``model`` — CLIP dual-tower with pluggable
    linear-layer precision; ``aot`` lowers loss-and-grads to HLO text for
    the rust L3 coordinator.

Nothing here is imported at runtime; ``make artifacts`` runs it once.
"""
