"""Model size presets and build matrix for AOT artifacts.

The paper trains CLIP ViT-Base / Large / Huge (up to ~1B params) on LAION-2B;
we keep the architecture family and scale it to CPU-trainable sizes (DESIGN.md
§Substitutions).  ``micro``→``small`` are the sweep workhorses (Fig 1/2/5–10);
``base``/``e2e100m`` exist for the end-to-end driver.

Images arrive pre-patchified from the rust data pipeline as
``[batch, patches, patch_dim]`` so the patch embedding is literally a linear
layer — the exact analogue of ``visual.conv1.weight``, the layer whose
out-of-date second-moment estimator the paper traces loss spikes to.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    dim: int
    vision_blocks: int
    text_blocks: int
    heads: int
    patches: int = 16        # 4×4 grid of patches
    patch_dim: int = 48      # 4×4 RGB patch, flattened
    seq: int = 16            # text sequence length
    vocab: int = 512
    embed_dim: int = 0       # shared CLIP embedding dim; 0 → == dim
    mlp_ratio: int = 4
    # Stability/precision knobs (paper §2.3, §3.2):
    layer_scale: bool = False        # zero-init layer-scale (Fig 5)
    kq_norm: bool = False            # KQ layernorm baseline (Fig 5)
    variant: str = "highprec"        # linear-layer precision variant

    @property
    def edim(self) -> int:
        return self.embed_dim or self.dim


SIZES = {
    "micro": dict(dim=64, vision_blocks=2, text_blocks=2, heads=4),
    "tiny": dict(dim=128, vision_blocks=3, text_blocks=3, heads=4),
    "small": dict(dim=256, vision_blocks=6, text_blocks=4, heads=8),
    "base": dict(dim=512, vision_blocks=12, text_blocks=8, heads=8),
    "e2e100m": dict(dim=768, vision_blocks=12, text_blocks=10, heads=12),
}


def make_config(size: str, variant: str = "highprec", layer_scale: bool = False,
                kq_norm: bool = False) -> ModelConfig:
    return ModelConfig(name=size, variant=variant, layer_scale=layer_scale,
                       kq_norm=kq_norm, **SIZES[size])


def param_count(cfg: ModelConfig) -> int:
    """Approximate parameter count (exact count comes from the manifest)."""
    d = cfg.dim
    block = 4 * d * d + 2 * d * cfg.mlp_ratio * d + 4 * d  # attn + mlp + lns
    n = (cfg.vision_blocks + cfg.text_blocks) * block
    n += cfg.patch_dim * d + cfg.vocab * d                  # embeddings
    n += (cfg.patches + cfg.seq) * d                        # pos embeds
    n += 2 * d * cfg.edim                                   # projections
    return n


# ---------------------------------------------------------------------------
# Build matrix: which (variant, size, batch) artifacts `make artifacts` emits.
# Experiments reference artifacts by these names (rust config presets too).
# ---------------------------------------------------------------------------

DEFAULT_BATCH = 32


@dataclass(frozen=True)
class Build:
    size: str
    variant: str
    batch: int = DEFAULT_BATCH
    layer_scale: bool = False
    kq_norm: bool = False
    with_encode: bool = True   # also emit the eval (encode) artifact

    @property
    def name(self) -> str:
        tags = []
        if self.layer_scale:
            tags.append("ls")
        if self.kq_norm:
            tags.append("kqn")
        tag = ("_" + "_".join(tags)) if tags else ""
        return f"{self.variant}_{self.size}{tag}_b{self.batch}"


# Fig 1/2: int8 + fp8 accuracy-vs-scale across three sizes.
_ACC_VARIANTS = ["highprec", "switchback_int8", "llmint8",
                 "fp8_tensorwise", "switchback_fp8"]
_ACC_SIZES = ["micro", "tiny", "small"]

BUILDS = (
    [Build(size=s, variant=v) for s in _ACC_SIZES for v in _ACC_VARIANTS]
    # Fig 5: fp8 tensor-wise rescue attempts at `small` (the paper's ViT-L slot)
    + [
        Build(size="small", variant="fp8_tensorwise", layer_scale=True),
        Build(size="small", variant="fp8_tensorwise", kq_norm=True),
        Build(size="small", variant="highprec", layer_scale=True),
    ]
    # Fig 7: batch-size sweep (micro so the sweep is cheap)
    + [Build(size="micro", variant="highprec", batch=b) for b in (8, 128, 512)]
    # Composition proof: a real Pallas-kernel artifact (quickstart loads this)
    + [Build(size="micro", variant="switchback_int8_pallas", batch=8,
             with_encode=False)]
    # End-to-end driver sizes
    + [Build(size="base", variant="switchback_int8", batch=16),
       Build(size="e2e100m", variant="highprec", batch=8, with_encode=False)]
)
