"""L2 — the CLIP model: dual tower + contrastive loss + grads.

Two entry points get AOT-lowered (``aot.py``):

* ``loss_and_grads(params, images, tokens)`` →
  ``(loss, block_magnitudes, *flat_grads)`` — the training-step compute.
  The optimizer deliberately does NOT live here: it is the paper's
  *stability* contribution (StableAdamW, update clipping, loss scalar) and
  is implemented in the rust coordinator (``rust/src/optim``), which
  consumes these gradients every step.
* ``encode(params, images, tokens)`` → ``(image_embs, text_embs)`` — the
  eval path (zero-shot-style classification is computed host-side in rust).

The contrastive loss is the standard symmetric InfoNCE of CLIP [46], with a
learnable ``logit_scale`` clipped to ≤ ln(100) (the paper clips logit_scale
even when not clipping gradients, §3.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import vit
from .configs import ModelConfig

MAX_LOG_SCALE = 4.6052  # ln(100), CLIP's logit_scale clip


def init_params(key, cfg: ModelConfig):
    kv, kt = jax.random.split(key)
    return {
        "visual": vit.init_vision_tower(kv, cfg),
        "text": vit.init_text_tower(kt, cfg),
        "logit_scale": jnp.asarray(jnp.log(1.0 / 0.07), jnp.float32),
    }


def encode(params, images, tokens, cfg: ModelConfig):
    """Embed both modalities, L2-normalized."""
    img, _ = vit.vision_forward(params["visual"], images, cfg)
    txt, _ = vit.text_forward(params["text"], tokens, cfg)
    img = img / jnp.linalg.norm(img, axis=-1, keepdims=True)
    txt = txt / jnp.linalg.norm(txt, axis=-1, keepdims=True)
    return img, txt


def clip_loss(params, images, tokens, cfg: ModelConfig):
    """Symmetric InfoNCE.  Aux output: per-block feature magnitudes
    (vision ++ text), the Fig 5/14 probe."""
    img, vmags = vit.vision_forward(params["visual"], images, cfg)
    txt, tmags = vit.text_forward(params["text"], tokens, cfg)
    img = img / jnp.linalg.norm(img, axis=-1, keepdims=True)
    txt = txt / jnp.linalg.norm(txt, axis=-1, keepdims=True)
    scale = jnp.exp(jnp.minimum(params["logit_scale"], MAX_LOG_SCALE))
    logits = scale * img @ txt.T
    labels = jnp.arange(logits.shape[0])
    li = jnp.mean(-jax.nn.log_softmax(logits, axis=1)[labels, labels])
    lt = jnp.mean(-jax.nn.log_softmax(logits, axis=0)[labels, labels])
    loss = 0.5 * (li + lt)
    return loss, jnp.concatenate([vmags, tmags])


def loss_and_grads(params, images, tokens, cfg: ModelConfig):
    """value_and_grad over :func:`clip_loss`; returns (loss, mags, grads)."""
    (loss, mags), grads = jax.value_and_grad(clip_loss, has_aux=True)(
        params, images, tokens, cfg)
    return loss, mags, grads


# ---------------------------------------------------------------------------
# Flattening: the HLO interface is a flat list of f32 tensors.  The manifest
# (aot.py) records the order, names, shapes, and optimizer metadata.
# ---------------------------------------------------------------------------


def flatten_params(params):
    """→ (list of leaves, list of dotted names, treedef)."""
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(params)
    names, leaves = [], []
    for path, leaf in leaves_with_path:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append(".".join(parts))
        leaves.append(leaf)
    return leaves, names, treedef


def param_metadata(name: str, shape) -> dict:
    """Optimizer metadata per tensor.

    * ``decay`` — weight decay applies to weight matrices only (not LN/bias/
      embeddings/scales), following OpenCLIP.
    * ``kind``  — tags the patch embedding (``visual.conv1.weight`` analogue,
      the Fig 9/16–21 probe target), embeddings, layer-scales, etc.
    """
    is_matrix = len(shape) == 2
    kind = "other"
    if "patch_embed" in name:
        kind = "patch_embed"
    elif "tok_embed" in name or name.endswith(".pos"):
        kind = "embedding"
    elif "logit_scale" in name:
        kind = "logit_scale"
    elif ".ls1" in name or ".ls2" in name:
        kind = "layer_scale"
    elif "ln" in name or "kqn" in name:
        kind = "norm"
    elif is_matrix:
        kind = "weight"
    decay = kind in ("weight", "patch_embed")
    return {"kind": kind, "decay": decay}
