"""Exact float8 value simulation (E4M3 / E5M2), as a Pallas kernel.

The paper (§2.2.1, "float8") simulates fp8 training by *rounding tensors to
the exact values representable in the float8 data type* while performing the
arithmetic in 16-bit — improving on Micikevicius et al. [40], which only
clips to the representable range.  We reproduce that methodology exactly:

* ``fp8_round_ref``   — pure-jnp round-to-nearest-even onto the fp8 grid,
  including subnormals and saturation.  Validated bit-exactly against
  ``ml_dtypes`` (``jnp.float8_e4m3fn`` / ``jnp.float8_e5m2``) in pytest.
* ``fp8_round``       — the same computation as a blocked element-wise Pallas
  kernel (the form that would run on-chip next to the matmul).

The arithmetic uses only f32 ops (frexp / round / clip), so the lowered HLO
contains no f8 types — important because the PJRT runtime we AOT into
(xla_extension 0.5.1) predates reliable f8 support.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclass(frozen=True)
class Fp8Format:
    """A float8 format description.

    ``max_value``       largest finite magnitude (saturation point)
    ``min_normal_exp``  exponent of the smallest normal number
    ``mantissa_bits``   explicit mantissa bits
    """

    name: str
    mantissa_bits: int
    min_normal_exp: int
    max_value: float


#: E4M3 in the "fn" (finite, no inf) flavour used by NVIDIA/ml_dtypes:
#: max 448, min normal 2^-6, subnormal quantum 2^-9.
E4M3 = Fp8Format("e4m3", mantissa_bits=3, min_normal_exp=-6, max_value=448.0)

#: E5M2 (IEEE-ish): max finite 57344, min normal 2^-14, quantum 2^-16.
E5M2 = Fp8Format("e5m2", mantissa_bits=2, min_normal_exp=-14, max_value=57344.0)

FORMATS = {"e4m3": E4M3, "e5m2": E5M2}


def _round_to_grid(x, fmt: Fp8Format):
    """Round f32 values to the nearest fp8-representable value (shared body
    between the jnp reference and the Pallas kernel — it is pure jnp math)."""
    a = jnp.abs(x)
    # frexp: a = m * 2^e with m in [0.5, 1)  =>  floor(log2(a)) == e - 1.
    _, e = jnp.frexp(a)
    e = jnp.maximum(e - 1, fmt.min_normal_exp)
    # Quantum (spacing of the fp8 grid at this magnitude).  ldexp is exact;
    # jnp.exp2 lowers to exp(x·ln2) on XLA:CPU and is off in the last bits,
    # which breaks bit-exactness against ml_dtypes.
    quantum = jnp.ldexp(jnp.float32(1.0), e - fmt.mantissa_bits)
    # jnp.round is round-half-to-even, matching IEEE round-to-nearest-even.
    q = jnp.round(a / quantum) * quantum
    # Saturating cast (paper divides by absmax first so saturation is rare,
    # but the kernel must still be total).
    q = jnp.minimum(q, fmt.max_value)
    return jnp.where(a == 0.0, 0.0, jnp.sign(x) * q).astype(x.dtype)


def fp8_round_ref(x, fmt: Fp8Format = E4M3):
    """Pure-jnp oracle: round ``x`` (f32) to exact fp8 values."""
    return _round_to_grid(jnp.asarray(x, jnp.float32), fmt)


def _fp8_kernel(x_ref, o_ref, *, fmt: Fp8Format):
    o_ref[...] = _round_to_grid(x_ref[...], fmt)


def fp8_round(x, fmt: Fp8Format = E4M3, block: int = 256):
    """Blocked element-wise Pallas kernel rounding ``x`` onto the fp8 grid.

    TPU mapping: one (block, lane) tile per grid step resident in VMEM; the
    op is purely element-wise so it fuses with neighbouring quantize /
    dequantize stages on real hardware.
    """
    x = jnp.asarray(x, jnp.float32)
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    grid = flat.shape[0] // block
    out = pl.pallas_call(
        functools.partial(_fp8_kernel, fmt=fmt),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat)
    return out[:n].reshape(shape)


def fp8_tensorwise_quant_ref(x, fmt: Fp8Format = E4M3):
    """Tensor-wise fp8 quantization: scale into the fp8 range by absmax (so
    the largest magnitude maps to ``max_value``), round to the grid, and
    return (values, state) just like the int8 path.

    Dequantization is ``values * state / max_value``.
    """
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(jnp.abs(x))
    state = jnp.where(m == 0.0, 1.0, m)
    scaled = x * (fmt.max_value / state)
    return _round_to_grid(scaled, fmt), state


def fp8_rowwise_quant_ref(x, fmt: Fp8Format = E4M3):
    """Row-wise fp8 quantization (SwitchBack-fp8 uses this for X and G)."""
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(jnp.abs(x), axis=-1)
    state = jnp.where(m == 0.0, 1.0, m)
    scaled = x * (fmt.max_value / state)[..., None]
    return _round_to_grid(scaled, fmt), state


def fp8_matmul_dequant_ref(xv, wv, state_x, state_w, fmt: Fp8Format = E4M3):
    """fp8 matmul + dequant: values are exact fp8 grid points carried in f32
    (arithmetic in ≥16-bit exactly as in the paper's simulation).

    ``xv [b, k]``, ``wv [m, k]``; ``state_x`` scalar or [b]; ``state_w``
    scalar.  Output [b, m] f32.
    """
    acc = xv @ wv.T
    sx = state_x / fmt.max_value
    sw = state_w / fmt.max_value
    if jnp.ndim(sx) == 1:
        sx = sx[:, None]
    return acc * sx * sw
