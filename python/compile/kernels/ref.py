"""Pure-jnp reference oracles for the L1 Pallas kernels.

Everything in this file is the *specification*: the Pallas kernels in
``quant.py`` / ``switchback.py`` / ``fp8.py`` must match these functions
bit-for-bit (int8 codes) or to float ULP (dequantized outputs).  The rust
``quant`` module mirrors the same definitions and is cross-checked against
golden vectors generated from here (see ``python/tests/test_golden.py``).

Conventions follow the paper (§2.2.1):

* ``Q_row(X)``  — row-wise int8 quantization, eq. (1): each row is scaled by
  ``127 / absmax(row)`` and rounded; the state is the vector of row absmaxes.
* ``Q_tensor(X)`` — tensor-wise int8 quantization, eq. (2).
* ``Q_col(X)`` — column-wise quantization (used by SwitchBackQ / LLM.int8()).
* dequantized matmul, eq. (3):
  ``state_tensor(W)/127^2 * state_row(X) * (Q_row(X) @ Q_tensor(W)^T)``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

INT8_MAX = 127.0


def _safe_absmax(a, axis=None, keepdims=False):
    """absmax with a floor so that all-zero tensors quantize to all-zero.

    The paper's kernels divide by absmax; for an all-zero row that is 0/0.
    Both bitsandbytes and our rust mirror treat absmax==0 as scale 1.
    """
    m = jnp.max(jnp.abs(a), axis=axis, keepdims=keepdims)
    return jnp.where(m == 0.0, 1.0, m)


def rowwise_quant_ref(x):
    """Row-wise int8 quantization, paper eq. (1).

    Returns ``(codes int8 [b, n], state f32 [b])`` where
    ``codes = round(127 * x / absmax(row))``.
    """
    state = _safe_absmax(x, axis=-1)
    codes = jnp.round(x * (INT8_MAX / state)[..., None])
    codes = jnp.clip(codes, -INT8_MAX, INT8_MAX)
    return codes.astype(jnp.int8), state


def colwise_quant_ref(x):
    """Column-wise int8 quantization (state per column)."""
    state = _safe_absmax(x, axis=0)
    codes = jnp.round(x * (INT8_MAX / state)[None, :])
    codes = jnp.clip(codes, -INT8_MAX, INT8_MAX)
    return codes.astype(jnp.int8), state


def tensorwise_quant_ref(x):
    """Tensor-wise int8 quantization, paper eq. (2).

    Returns ``(codes int8, state f32 scalar)``.
    """
    state = _safe_absmax(x)
    codes = jnp.round(x * (INT8_MAX / state))
    codes = jnp.clip(codes, -INT8_MAX, INT8_MAX)
    return codes.astype(jnp.int8), state


def dequant_rowwise_ref(codes, state):
    """Inverse of :func:`rowwise_quant_ref` (up to rounding)."""
    return codes.astype(jnp.float32) * (state / INT8_MAX)[..., None]


def int8_matmul_dequant_ref(x_codes, w_codes, state_x, state_w):
    """int8 matmul + dequantize, paper eq. (3).

    ``x_codes [b, k] int8``, ``w_codes [m, k] int8`` (weights stored row-major
    as in ``nn.Linear``), ``state_x [b]`` row-wise state, ``state_w`` scalar
    tensor-wise state.  Accumulation in int32 — exact, as on real int8 MMA
    hardware.  Output ``[b, m] f32``.
    """
    acc = lax.dot_general(
        x_codes,
        w_codes,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    scale = (state_x / INT8_MAX)[:, None] * (state_w / INT8_MAX)
    return acc.astype(jnp.float32) * scale


def int8_matmul_dequant_rowcol_ref(x_codes, w_codes, state_x, state_w_col):
    """int8 matmul where both operands have per-vector states.

    Used by SwitchBackQ / LLM.int8(): ``x`` row-wise, ``w`` row-wise over its
    own rows (i.e. per output unit).  ``w_codes [m, k]``, ``state_w_col [m]``.
    Output ``[b, m] f32`` (paper eq. (4)).
    """
    acc = lax.dot_general(
        x_codes,
        w_codes,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    scale = (state_x / INT8_MAX)[:, None] * (state_w_col / INT8_MAX)[None, :]
    return acc.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Whole-layer references (forward + both gradient matmuls).
# ---------------------------------------------------------------------------


def linear_fwd_ref(x, w):
    """Standard full-precision linear forward: ``Y = X W^T``."""
    return x @ w.T


def switchback_fwd_ref(x, w):
    """SwitchBack forward (Algorithm 1): row-wise X, tensor-wise W, int8."""
    xq, sx = rowwise_quant_ref(x)
    wq, sw = tensorwise_quant_ref(w)
    return int8_matmul_dequant_ref(xq, wq, sx, sw)


def switchback_dgrad_ref(g, w):
    """SwitchBack input gradient: ``dX = G W`` with G row-wise, W tensor-wise.

    The int8 matmul contracts over ``m`` so we hand it ``W^T [n, m]`` —
    mirroring the paper's fused ``tensor-wise_quantize_transpose``.
    """
    gq, sg = rowwise_quant_ref(g)
    wq, sw = tensorwise_quant_ref(w.T)
    return int8_matmul_dequant_ref(gq, wq, sg, sw)


def switchback_wgrad_ref(g, x):
    """SwitchBack weight gradient — kept in high precision (the whole point):
    ``dW = G^T X`` with inner dimension b = batch*seq."""
    return g.T @ x


def switchback_linear_ref(x, w):
    """(fwd, dgrad, wgrad) triple for a given upstream gradient of ones —
    convenience for golden-vector generation."""
    y = switchback_fwd_ref(x, w)
    g = jnp.ones_like(y)
    return y, switchback_dgrad_ref(g, w), switchback_wgrad_ref(g, x)


def llmint8_fwd_ref(x, w):
    """LLM.int8()-style forward: row-wise X, row-wise (per-output) W."""
    xq, sx = rowwise_quant_ref(x)
    wq, sw = rowwise_quant_ref(w)
    return int8_matmul_dequant_rowcol_ref(xq, wq, sx, sw)


def llmint8_dgrad_ref(g, w):
    """LLM.int8() input gradient: G row-wise, W^T column-wise-per-output."""
    gq, sg = rowwise_quant_ref(g)
    wq, sw = rowwise_quant_ref(w.T)
    return int8_matmul_dequant_rowcol_ref(gq, wq, sg, sw)


def llmint8_wgrad_ref(g, x):
    """LLM.int8() weight gradient *also* in int8 — the failure mode the paper
    identifies (inner dim = batch*seq is huge, quantization noise ∝ k).

    ``dW = Gᵀ X``: G is quantized row-wise along the contraction (per output
    unit), X column-wise (per input feature); the contraction runs over
    b = batch×seq.
    """
    gq, sg = rowwise_quant_ref(g.T)   # [m, b], state [m]
    xq, sxc = colwise_quant_ref(x)    # [b, n], state [n]
    return int8_matmul_dequant_rowcol_ref(gq, xq.T, sg, sxc)
