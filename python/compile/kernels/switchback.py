"""Fused int8-matmul-and-dequantize Pallas kernel + SwitchBack layer ops.

This is the paper's compute hot-spot (Algorithm 1) rendered for the TPU
programming model:

* grid ``(M/bm, N/bn, K/bk)`` with the K dimension innermost; an int32 VMEM
  scratch accumulator plays the role of the MXU accumulator tile.  On the
  last K step the dequantize epilogue (``state_row(X) ⊗ state(W) / 127²``)
  is applied in-register and the f32 tile is written out — this is the
  paper's fused ``matmul_int8_and_dequantize``.
* block sizes default to 128×128×128: MXU-systolic-array aligned, and the
  three tiles (int8 X, int8 W, int32 acc) occupy
  ``bm·bk + bk·bn + 4·bm·bn ≈ 96 KiB`` — far under the ~16 MiB VMEM budget,
  leaving room for double buffering (see EXPERIMENTS.md §Perf for the
  footprint/utilization table).

``interpret=True`` everywhere: the CPU PJRT runtime cannot execute Mosaic
custom-calls.  Numerics are exact either way (int32 accumulation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import quant
from .quant import INT8_MAX, _pad_to


def _mm_dequant_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, nk: int):
    """One (bm, bn) output tile; accumulates int8·int8 → int32 over K steps."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        scale = (sx_ref[...] / INT8_MAX)[:, None] * (sw_ref[0] / INT8_MAX)
        o_ref[...] = acc_ref[...].astype(jnp.float32) * scale


def int8_matmul_dequant(
    x_codes,
    w_codes,
    state_x,
    state_w,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
):
    """Fused int8 matmul + dequantize (paper eq. (3)).

    ``x_codes [b, k] int8`` (row-wise quantized, ``state_x [b]``),
    ``w_codes [m, k] int8`` (tensor-wise quantized, scalar ``state_w``).
    Returns ``[b, m] f32``.
    """
    b, k = x_codes.shape
    m, k2 = w_codes.shape
    assert k == k2, f"inner dims disagree: {k} vs {k2}"
    xq, _ = _pad_to(x_codes, block_m, 0)
    xq, _ = _pad_to(xq, block_k, 1)
    wq, _ = _pad_to(w_codes, block_n, 0)
    wq, _ = _pad_to(wq, block_k, 1)
    sx, _ = _pad_to(state_x, block_m, 0)
    bp, kp = xq.shape
    mp = wq.shape[0]
    nk = kp // block_k
    grid = (bp // block_m, mp // block_n, nk)
    out = pl.pallas_call(
        functools.partial(_mm_dequant_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_n, block_k), lambda i, j, s: (j, s)),
            pl.BlockSpec((block_m,), lambda i, j, s: (i,)),
            pl.BlockSpec((1,), lambda i, j, s: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=True,
    )(xq, wq, sx, jnp.asarray(state_w)[None])
    return out[:b, :m]


def _mm_dequant_rowcol_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        scale = (sx_ref[...] / INT8_MAX)[:, None] * (sw_ref[...] / INT8_MAX)[None, :]
        o_ref[...] = acc_ref[...].astype(jnp.float32) * scale


def int8_matmul_dequant_rowcol(
    x_codes,
    w_codes,
    state_x,
    state_w,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
):
    """Row×row int8 matmul + dequantize (paper eq. (4) — SwitchBackQ /
    LLM.int8() style, per-output-unit weight states ``state_w [m]``)."""
    b, k = x_codes.shape
    m, _ = w_codes.shape
    xq, _ = _pad_to(x_codes, block_m, 0)
    xq, _ = _pad_to(xq, block_k, 1)
    wq, _ = _pad_to(w_codes, block_n, 0)
    wq, _ = _pad_to(wq, block_k, 1)
    sx, _ = _pad_to(state_x, block_m, 0)
    sw, _ = _pad_to(state_w, block_n, 0)
    bp, kp = xq.shape
    mp = wq.shape[0]
    nk = kp // block_k
    grid = (bp // block_m, mp // block_n, nk)
    out = pl.pallas_call(
        functools.partial(_mm_dequant_rowcol_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_n, block_k), lambda i, j, s: (j, s)),
            pl.BlockSpec((block_m,), lambda i, j, s: (i,)),
            pl.BlockSpec((block_n,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=True,
    )(xq, wq, sx, sw)
    return out[:b, :m]


# ---------------------------------------------------------------------------
# Whole-layer SwitchBack ops built from the kernels (Algorithm 1).
# These are what L2 (`compile/layers.py`) calls when `use_kernels=True`.
# ---------------------------------------------------------------------------


def switchback_fwd(x, w):
    """Forward: ``Y = Q_row(X) Q_tensor(W)ᵀ`` dequantized — all Pallas."""
    xq, sx = quant.rowwise_quant(x)
    wq, sw = quant.tensorwise_quant(w)
    return int8_matmul_dequant(xq, wq, sx, sw)


def switchback_dgrad(g, w):
    """Input gradient: ``dX = Q_row(G) Q_tensor(Wᵀ)ᵀ`` — uses the fused
    quantize+transpose kernel exactly as Algorithm 1's backward."""
    gq, sg = quant.rowwise_quant(g)
    wtq, sw = quant.tensorwise_quant_transpose(w)
    return int8_matmul_dequant(gq, wtq, sg, sw)


def switchback_wgrad(g, x):
    """Weight gradient in high precision (``matmul_fp16`` in Algorithm 1):
    the inner dimension is batch×seq, where quantization noise would be
    catastrophic (paper Appendix C)."""
    return g.T @ x
