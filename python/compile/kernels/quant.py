"""Pallas quantization kernels (int8, row-wise / tensor-wise / fused transpose).

TPU adaptation of the paper's Triton kernels (DESIGN.md §Hardware-Adaptation):

* Triton loads a row tile into SRAM, reduces absmax, scales + rounds in
  registers.  Here each grid step holds a ``(block_rows, n)`` tile in VMEM,
  reduces along the lane dimension, and writes int8 codes plus the f32 state.
* The paper's ``tensor-wise_quantize_transpose`` fusion (one DRAM round-trip
  for quantize+transpose, §2.2.1) maps to a kernel whose *output* BlockSpec
  index map is the transpose of its input map — the tile is transposed while
  VMEM-resident, so HBM sees exactly one read and one write.

All kernels are total: absmax==0 rows quantize to zero codes with state 1
(matching ``ref._safe_absmax`` and the rust mirror).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT8_MAX = 127.0


def _pad_to(x, multiple, axis):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def _rowwise_kernel(x_ref, codes_ref, state_ref):
    x = x_ref[...]
    m = jnp.max(jnp.abs(x), axis=-1)
    state = jnp.where(m == 0.0, 1.0, m)
    codes = jnp.round(x * (INT8_MAX / state)[:, None])
    codes_ref[...] = jnp.clip(codes, -INT8_MAX, INT8_MAX).astype(jnp.int8)
    state_ref[...] = state


def rowwise_quant(x, block_rows: int = 128):
    """Row-wise int8 quantization (paper eq. (1)) as a Pallas kernel.

    ``x [b, n] f32`` → ``(codes [b, n] int8, state [b] f32)``.  Grid over row
    blocks; each step's VMEM working set is ``block_rows × n`` f32 in +
    int8 out.
    """
    x = jnp.asarray(x, jnp.float32)
    b, n = x.shape
    xp, _ = _pad_to(x, block_rows, 0)
    bp = xp.shape[0]
    grid = bp // block_rows
    codes, state = pl.pallas_call(
        _rowwise_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, n), jnp.int8),
            jax.ShapeDtypeStruct((bp,), jnp.float32),
        ],
        interpret=True,
    )(xp)
    return codes[:b], state[:b]


def _scale_round_kernel(x_ref, state_ref, codes_ref):
    x = x_ref[...]
    scale = INT8_MAX / state_ref[0]
    codes = jnp.round(x * scale)
    codes_ref[...] = jnp.clip(codes, -INT8_MAX, INT8_MAX).astype(jnp.int8)


def tensorwise_quant(x, block_rows: int = 128):
    """Tensor-wise int8 quantization (paper eq. (2)).

    The global absmax is a cheap O(n²) reduction done by XLA (it fuses with
    whatever produced ``x``); the scale+round is the Pallas kernel.  Returns
    ``(codes int8, state f32 scalar)``.
    """
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(jnp.abs(x))
    state = jnp.where(m == 0.0, 1.0, m)
    b, n = x.shape
    xp, _ = _pad_to(x, block_rows, 0)
    bp = xp.shape[0]
    grid = bp // block_rows
    codes = pl.pallas_call(
        _scale_round_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, n), jnp.int8),
        interpret=True,
    )(xp, state[None])
    return codes[:b], state


def _quant_transpose_kernel(w_ref, state_ref, out_ref):
    w = w_ref[...]
    scale = INT8_MAX / state_ref[0]
    codes = jnp.round(w.T * scale)
    out_ref[...] = jnp.clip(codes, -INT8_MAX, INT8_MAX).astype(jnp.int8)


def tensorwise_quant_transpose(w, block: int = 128):
    """Fused tensor-wise quantize + transpose (the paper's
    ``tensor-wise_quantize_transpose``; critical for the backward pass since
    int8 MMA hardware only implements ``A Bᵀ``).

    ``w [m, n] f32`` → ``(codes [n, m] int8, state f32 scalar)``.  Each grid
    step reads one (block, block) tile, transposes it in VMEM, and writes it
    to the transposed tile position — one HBM read + one HBM write total.
    """
    w = jnp.asarray(w, jnp.float32)
    m, n = w.shape
    mx = jnp.max(jnp.abs(w))
    state = jnp.where(mx == 0.0, 1.0, mx)
    wp, _ = _pad_to(w, block, 0)
    wp, _ = _pad_to(wp, block, 1)
    mp, np_ = wp.shape
    grid = (mp // block, np_ // block)
    codes = pl.pallas_call(
        _quant_transpose_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((np_, mp), jnp.int8),
        interpret=True,
    )(wp, state[None])
    return codes[:n, :m], state


def _dequant_rowwise_kernel(codes_ref, state_ref, out_ref):
    out_ref[...] = codes_ref[...].astype(jnp.float32) * (
        state_ref[...] / INT8_MAX
    )[:, None]


def dequant_rowwise(codes, state, block_rows: int = 128):
    """Dequantize row-wise int8 codes back to f32 (used by SwitchBackM's
    memory-efficient backward, Algorithm 3)."""
    b, n = codes.shape
    cp, _ = _pad_to(codes, block_rows, 0)
    sp, _ = _pad_to(state, block_rows, 0)
    bp = cp.shape[0]
    grid = bp // block_rows
    out = pl.pallas_call(
        _dequant_rowwise_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, n), jnp.float32),
        interpret=True,
    )(cp, sp)
    return out[:b]
