"""L1 — Pallas kernels for SwitchBack low-precision training.

``ref``        pure-jnp specification (oracles for pytest + rust goldens)
``quant``      Pallas quantization kernels (row/tensor-wise int8, fused
               quantize+transpose)
``switchback`` Pallas fused int8-matmul-and-dequantize + whole-layer ops
``fp8``        exact float8 (E4M3/E5M2) value simulation

All Pallas kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU performance is estimated from the
BlockSpecs (see DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md §Perf).
"""

from . import fp8, quant, ref, switchback  # noqa: F401
