"""fp8 simulation correctness: our arithmetic emulation must agree
bit-exactly with ml_dtypes' float8 types (within range), and the Pallas
kernel must agree with the jnp reference."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def via_mldtypes(x, dtype):
    return np.asarray(jnp.asarray(x).astype(dtype).astype(jnp.float32))


@given(seed=st.integers(0, 2**31), scale=st.sampled_from([1e-4, 1e-2, 1.0, 50.0, 400.0]))
def test_e4m3_matches_mldtypes_bit_exactly(seed, scale):
    x = scale * jax.random.normal(jax.random.PRNGKey(seed), (2048,))
    x = jnp.clip(x, -448.0, 448.0)
    ours = np.asarray(fp8.fp8_round_ref(x, fp8.E4M3))
    theirs = via_mldtypes(x, jnp.float8_e4m3fn)
    np.testing.assert_array_equal(ours, theirs)


@given(seed=st.integers(0, 2**31), scale=st.sampled_from([1e-4, 1.0, 1000.0, 5e4]))
def test_e5m2_matches_mldtypes_bit_exactly(seed, scale):
    x = scale * jax.random.normal(jax.random.PRNGKey(seed), (2048,))
    x = jnp.clip(x, -57344.0, 57344.0)
    ours = np.asarray(fp8.fp8_round_ref(x, fp8.E5M2))
    theirs = via_mldtypes(x, jnp.float8_e5m2)
    np.testing.assert_array_equal(ours, theirs)


def test_subnormal_grid_e4m3():
    # E4M3 subnormals: multiples of 2^-9 below 2^-6
    q = 2.0 ** -9
    for m in range(8):
        v = m * q
        assert float(fp8.fp8_round_ref(jnp.array(v))) == v
    # halfway rounds to even
    assert float(fp8.fp8_round_ref(jnp.array(1.5 * q))) == 2 * q
    assert float(fp8.fp8_round_ref(jnp.array(0.5 * q))) == 0.0


def test_saturation():
    assert float(fp8.fp8_round_ref(jnp.array(1e9))) == 448.0
    assert float(fp8.fp8_round_ref(jnp.array(-1e9))) == -448.0


@given(n=st.integers(1, 3000), seed=st.integers(0, 2**31))
def test_pallas_kernel_matches_ref(n, seed):
    x = 100.0 * jax.random.normal(jax.random.PRNGKey(seed), (n,))
    got = np.asarray(fp8.fp8_round(x))
    want = np.asarray(fp8.fp8_round_ref(x))
    np.testing.assert_array_equal(got, want)


def test_pallas_kernel_2d_shapes():
    x = jax.random.normal(jax.random.PRNGKey(0), (37, 53))
    got = np.asarray(fp8.fp8_round(x))
    want = np.asarray(fp8.fp8_round_ref(x))
    assert got.shape == (37, 53)
    np.testing.assert_array_equal(got, want)


@given(seed=st.integers(0, 2**31))
def test_tensorwise_fp8_quant_dequant_error_bounded(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64, 64))
    v, state = fp8.fp8_tensorwise_quant_ref(x)
    back = np.asarray(v) * float(state) / fp8.E4M3.max_value
    # e4m3 relative error ≤ 2^-4 per value for normals (3 mantissa bits)
    err = np.abs(back - np.asarray(x))
    tol = np.maximum(np.abs(np.asarray(x)) * 2.0**-4, float(state) * 2.0**-9)
    assert np.all(err <= tol + 1e-7)


def test_fp8_matmul_dequant_identity_scaling():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    w = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    xv, sx = fp8.fp8_rowwise_quant_ref(x)
    wv, sw = fp8.fp8_tensorwise_quant_ref(w)
    out = fp8.fp8_matmul_dequant_ref(xv, wv, sx, sw)
    exact = x @ w.T
    rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
    assert rel < 0.1, rel
