"""L1 correctness: Pallas quantization kernels vs the pure-jnp oracles.

The hypothesis sweeps are the contract: for ANY shape/seed in range, the
Pallas kernel must agree with ref.py — int8 codes bit-for-bat, dequantized
floats to tight tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref, switchback

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def randn(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


# ---------------------------------------------------------------------------
# row-wise / tensor-wise quantization
# ---------------------------------------------------------------------------


@given(
    rows=st.integers(1, 300),
    cols=st.integers(1, 80),
    seed=st.integers(0, 2**31),
    scale=st.sampled_from([1e-3, 1.0, 100.0]),
)
def test_rowwise_quant_matches_ref(rows, cols, seed, scale):
    x = randn(seed, (rows, cols), scale)
    kc, ks = quant.rowwise_quant(x)
    rc, rs = ref.rowwise_quant_ref(x)
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
    np.testing.assert_allclose(np.asarray(ks), np.asarray(rs), rtol=1e-6)


@given(rows=st.integers(1, 200), cols=st.integers(1, 64), seed=st.integers(0, 2**31))
def test_tensorwise_quant_matches_ref(rows, cols, seed):
    x = randn(seed, (rows, cols))
    kc, ks = quant.tensorwise_quant(x)
    rc, rs = ref.tensorwise_quant_ref(x)
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
    assert float(ks) == pytest.approx(float(rs))


@given(rows=st.integers(1, 150), cols=st.integers(1, 150), seed=st.integers(0, 2**31))
def test_quant_transpose_is_quant_then_transpose(rows, cols, seed):
    w = randn(seed, (rows, cols))
    kc, ks = quant.tensorwise_quant_transpose(w)
    rc, rs = ref.tensorwise_quant_ref(w)
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc).T)
    assert float(ks) == pytest.approx(float(rs))


def test_zero_input_is_total():
    x = jnp.zeros((5, 7))
    kc, ks = quant.rowwise_quant(x)
    assert np.all(np.asarray(kc) == 0)
    assert np.all(np.asarray(ks) == 1.0)


def test_extreme_values_clip_to_int8_range():
    x = jnp.array([[1e30, -1e30, 1.0]])
    kc, _ = quant.rowwise_quant(x)
    arr = np.asarray(kc)
    assert arr.min() >= -127 and arr.max() <= 127


@given(rows=st.integers(1, 100), cols=st.integers(1, 50), seed=st.integers(0, 2**31))
def test_dequant_roundtrip_error_bounded(rows, cols, seed):
    x = randn(seed, (rows, cols))
    c, s = quant.rowwise_quant(x)
    back = quant.dequant_rowwise(c, s)
    step = np.asarray(s)[:, None] / 127.0
    assert np.all(np.abs(np.asarray(back) - np.asarray(x)) <= 0.5 * step + 1e-6)


# ---------------------------------------------------------------------------
# fused int8 matmul + dequant
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 70),
    k=st.integers(1, 90),
    m=st.integers(1, 70),
    seed=st.integers(0, 2**31),
)
def test_int8_matmul_dequant_matches_ref(b, k, m, seed):
    x = randn(seed, (b, k))
    w = randn(seed + 1, (m, k))
    xq, sx = ref.rowwise_quant_ref(x)
    wq, sw = ref.tensorwise_quant_ref(w)
    got = switchback.int8_matmul_dequant(xq, wq, sx, sw)
    want = ref.int8_matmul_dequant_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


@given(
    b=st.integers(1, 50),
    k=st.integers(1, 70),
    m=st.integers(1, 50),
    seed=st.integers(0, 2**31),
)
def test_int8_matmul_rowcol_matches_ref(b, k, m, seed):
    x = randn(seed, (b, k))
    w = randn(seed + 1, (m, k))
    xq, sx = ref.rowwise_quant_ref(x)
    wq, sw = ref.rowwise_quant_ref(w)
    got = switchback.int8_matmul_dequant_rowcol(xq, wq, sx, sw)
    want = ref.int8_matmul_dequant_rowcol_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_int8_matmul_accumulates_in_int32():
    # 256 * (127*127) = 4129024 > 2^16: breaks if accumulation is narrow;
    # exact int32 accumulation reproduces it bit-for-bit after dequant.
    k = 256
    x = jnp.ones((1, k))
    w = jnp.ones((1, k))
    xq, sx = ref.rowwise_quant_ref(x)
    wq, sw = ref.tensorwise_quant_ref(w)
    out = switchback.int8_matmul_dequant(xq, wq, sx, sw)
    assert float(out[0, 0]) == pytest.approx(k, rel=1e-6)


def test_blocks_smaller_than_problem():
    # grid > 1 in every dimension exercises the K-accumulation loop
    x = randn(3, (300, 260))
    w = randn(4, (290, 260))
    xq, sx = ref.rowwise_quant_ref(x)
    wq, sw = ref.tensorwise_quant_ref(w)
    got = switchback.int8_matmul_dequant(xq, wq, sx, sw, block_m=128, block_n=128, block_k=128)
    want = ref.int8_matmul_dequant_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


# ---------------------------------------------------------------------------
# whole-layer SwitchBack ops
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 64),
    n=st.integers(1, 64),
    m=st.integers(1, 64),
    seed=st.integers(0, 2**31),
)
def test_switchback_fwd_dgrad_match_ref(b, n, m, seed):
    x = randn(seed, (b, n))
    w = randn(seed + 1, (m, n), 0.1)
    g = randn(seed + 2, (b, m))
    np.testing.assert_allclose(
        np.asarray(switchback.switchback_fwd(x, w)),
        np.asarray(ref.switchback_fwd_ref(x, w)),
        atol=1e-4, rtol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(switchback.switchback_dgrad(g, w)),
        np.asarray(ref.switchback_dgrad_ref(g, w)),
        atol=1e-4, rtol=1e-4,
    )


def test_switchback_quantization_noise_is_small():
    x = randn(0, (128, 256))
    w = randn(1, (64, 256), 0.05)
    exact = x @ w.T
    q = ref.switchback_fwd_ref(x, w)
    rel = float(jnp.linalg.norm(q - exact) / jnp.linalg.norm(exact))
    assert rel < 0.03, rel
