"""L2 correctness: precision-pluggable linear layers (custom VJPs) and the
CLIP model (shapes, loss, gradient structure, variant parity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, layers, model
from compile.kernels import ref


def randn(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


# ---------------------------------------------------------------------------
# custom VJPs implement exactly the paper's backward rules
# ---------------------------------------------------------------------------


def test_switchback_vjp_uses_quantized_dgrad_and_exact_wgrad():
    x = randn(0, (32, 24))
    w = randn(1, (16, 24), 0.1)
    g = randn(2, (32, 16))
    y, vjp = jax.vjp(lambda x, w: layers.linear_switchback_int8(x, w), x, w)
    dx, dw = vjp(g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.switchback_fwd_ref(x, w)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(ref.switchback_dgrad_ref(g, w)), atol=1e-5)
    # wgrad must be the EXACT high-precision product (Algorithm 1)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(g.T @ x), atol=1e-5)


def test_llmint8_vjp_quantizes_wgrad_too():
    x = randn(3, (32, 24))
    w = randn(4, (16, 24), 0.1)
    g = randn(5, (32, 16))
    _, vjp = jax.vjp(lambda x, w: layers.linear_llmint8(x, w), x, w)
    _, dw = vjp(g)
    exact = np.asarray(g.T @ x)
    got = np.asarray(dw)
    np.testing.assert_allclose(got, np.asarray(ref.llmint8_wgrad_ref(g, x)), atol=1e-5)
    # and it is NOT the exact product (quantization noise present)
    assert np.abs(got - exact).max() > 1e-4


def test_pallas_and_jnp_switchback_paths_agree():
    x = randn(6, (48, 40))
    w = randn(7, (24, 40), 0.1)
    y_jnp = layers.linear_switchback_int8(x, w, use_kernels=False)
    y_pls = layers.linear_switchback_int8(x, w, use_kernels=True)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pls), atol=1e-4)
    g = randn(8, (48, 24))
    _, vjp_a = jax.vjp(lambda x, w: layers.linear_switchback_int8(x, w, False), x, w)
    _, vjp_b = jax.vjp(lambda x, w: layers.linear_switchback_int8(x, w, True), x, w)
    for a, b in zip(vjp_a(g), vjp_b(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_highprec_linear_grad_is_standard():
    x = randn(9, (8, 6))
    w = randn(10, (4, 6))
    g = randn(11, (8, 4))
    _, vjp = jax.vjp(lambda x, w: layers.linear_highprec(x, w), x, w)
    dx, dw = vjp(g)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(g @ w), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(g.T @ x), atol=1e-5)


def test_fp8_tensorwise_linear_is_close_but_not_exact():
    x = randn(12, (32, 24))
    w = randn(13, (16, 24), 0.1)
    y = layers.linear_fp8_tensorwise(x, w)
    exact = x @ w.T
    rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
    assert 0 < rel < 0.15, rel


def test_apply_linear_handles_3d():
    x = randn(14, (4, 5, 8))
    w = randn(15, (6, 8))
    y = layers.apply_linear("switchback_int8", x, w)
    assert y.shape == (4, 5, 6)


# ---------------------------------------------------------------------------
# model-level properties
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def micro_setup():
    cfg = configs.make_config("micro")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    B = 8
    imgs = randn(20, (B, cfg.patches, cfg.patch_dim))
    toks = jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab, size=(B, cfg.seq)), jnp.int32
    )
    return cfg, params, imgs, toks


def test_loss_at_init_is_ln_batch(micro_setup):
    cfg, params, imgs, toks = micro_setup
    loss, mags = model.clip_loss(params, imgs, toks, cfg)
    # at init embeddings are ~random: loss ≈ ln(B)
    assert abs(float(loss) - np.log(imgs.shape[0])) < 0.5
    assert mags.shape == (cfg.vision_blocks + cfg.text_blocks,)


def test_grads_cover_every_parameter(micro_setup):
    cfg, params, imgs, toks = micro_setup
    _, _, grads = model.loss_and_grads(params, imgs, toks, cfg)
    leaves, names, _ = model.flatten_params(params)
    gleaves = jax.tree_util.tree_leaves(grads)
    assert len(gleaves) == len(leaves)
    nonzero = sum(bool(np.any(np.asarray(g) != 0)) for g in gleaves)
    # everything should receive gradient except possibly a few norms
    assert nonzero >= len(gleaves) - 2, f"{nonzero}/{len(gleaves)}"


def test_encode_embeddings_are_normalized(micro_setup):
    cfg, params, imgs, toks = micro_setup
    img, txt = model.encode(params, imgs, toks, cfg)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(img), axis=-1), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(txt), axis=-1), 1.0, atol=1e-5)


def test_layer_scale_zero_init_makes_towers_identity_like():
    cfg = configs.make_config("micro", layer_scale=True)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    imgs = randn(21, (4, cfg.patches, cfg.patch_dim))
    toks = jnp.zeros((4, cfg.seq), jnp.int32)
    _, mags = model.clip_loss(params, imgs, toks, cfg)
    # with γ=0 every block is the identity: magnitudes are constant across depth
    vm = np.asarray(mags[: cfg.vision_blocks])
    assert np.allclose(vm, vm[0], rtol=1e-4), vm


def test_variant_losses_agree_at_init():
    # quantization is noise, not bias: all variants should start near ln(B)
    imgs = randn(22, (8, 16, 48))
    toks = jnp.zeros((8, 16), jnp.int32)
    losses = {}
    for variant in ["highprec", "switchback_int8", "llmint8", "fp8_tensorwise",
                    "switchback_fp8"]:
        cfg = configs.make_config("micro", variant=variant)
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        loss, _ = model.clip_loss(params, imgs, toks, cfg)
        losses[variant] = float(loss)
    base = losses["highprec"]
    for v, l in losses.items():
        assert abs(l - base) < 0.2, f"{v}: {l} vs {base}"


def test_param_metadata_tags():
    assert model.param_metadata("visual.patch_embed", (64, 48))["kind"] == "patch_embed"
    assert model.param_metadata("visual.patch_embed", (64, 48))["decay"] is True
    assert model.param_metadata("text.tok_embed", (512, 64))["kind"] == "embedding"
    assert model.param_metadata("visual.blocks.0.ln1.g", (64,))["decay"] is False
    assert model.param_metadata("visual.blocks.0.ls1", (64,))["kind"] == "layer_scale"
    assert model.param_metadata("logit_scale", ())["kind"] == "logit_scale"
    assert model.param_metadata("visual.blocks.0.attn.wq", (64, 64))["decay"] is True
